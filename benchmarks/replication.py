"""Demand-driven replication vs cache-only: zipf readers over a WAN.

The data-intensive half of the paper assumes hot datasets end up *near*
the clusters that read them (Pilot-Data / DIRAC-style placement).  This
suite measures what the replication plane actually buys on the worst
realistic shape: a single origin cluster behind a thin WAN pipe, several
edge clusters whose readers sample a dataset catalog zipf-style, and
Content Stores too small to pin the working set.

Two runs of the identical seeded workload:

* **cache-only** — edge Content Stores are the only locality; every CS
  miss re-crosses the shared WAN uplink;
* **replicated** — one :class:`ReplicationManager` per edge (byte
  budget, hysteresis, durable retries) pulls hot datasets once and then
  serves them locally as a registered producer.

Reported gates (all higher-is-better for the CI regression check):

* ``goodput_speedup``   — aggregate reader goodput, replicated over
  cache-only (floor 2x in smoke);
* ``origin_offload``    — fraction of origin WAN egress removed
  (floor 0.5 in smoke);
* ``delivery``          — completed reads / issued reads (must be 1.0);

plus invariant asserts: every manager's ``max_bytes_used`` stays under
its budget at every instant, every replica byte-matches the origin lake
(``audit``), and the replicated run is replay-identical on the calendar
and heap event engines.

``--smoke`` runs the CI-sized configuration and writes
``BENCH_replication.json`` at the repo root for
``scripts/check_bench_regression.py``.
"""

from __future__ import annotations

import argparse
import random
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, "src")  # allow running as a script from the repo root

from _bench_io import write_bench_json  # noqa: E402
from repro.core.forwarder import Forwarder, Network, link  # noqa: E402
from repro.core.names import Name  # noqa: E402
from repro.datalake import (DataLake, ReplicationManager,  # noqa: E402
                            ReplicationPolicy, SegmentFetcher)

MB = 2 ** 20
DATA = Name.parse("/lidc/data")

GATE_METRICS = [
    "goodput_speedup",
    "origin_offload",
    "replicated_goodput_mbps",
    "delivery",
    "replica_serve_fraction",
]


class WanPlane:
    """origin -- hub -- N edges, readers hanging off each edge.

    The origin-hub WAN link is the thin shared pipe; hub-edge and
    client-edge links are LAN-fast.  One manager per edge when armed.
    """

    def __init__(self, *, engine: str, n_edges: int, segment: int,
                 wan_bw: float, edge_cs_bytes: int,
                 policy: Optional[ReplicationPolicy]):
        self.net = Network(engine=engine)
        self.origin = Forwarder(self.net, "origin")
        self.hub = Forwarder(self.net, "hub", cs_capacity_bytes=segment * 4)
        fh, self.fo = link(self.net, self.hub, self.origin, 0.02)
        fh.bandwidth = self.fo.bandwidth = wan_bw
        self.hub.register_route(DATA, fh)
        self.lake = DataLake(segment_size=segment)
        self.lake.attach(self.origin)
        self.edges: List[Forwarder] = []
        self.clients: List[Forwarder] = []
        self.managers: List[ReplicationManager] = []
        if policy is not None:
            # two-tier, still decentralized: the hub manager sees the
            # *aggregate* cross-edge miss stream, so one pull over the
            # thin WAN pipe serves every edge behind it
            self.managers.append(
                ReplicationManager(self.net, self.hub, policy=policy,
                                   name="hub-repl").start())
        for i in range(n_edges):
            edge = Forwarder(self.net, f"edge{i}",
                             cs_capacity_bytes=edge_cs_bytes)
            fe, fhub = link(self.net, edge, self.hub, 0.002)
            fe.bandwidth = fhub.bandwidth = 20 * wan_bw
            edge.register_route(DATA, fe)
            client = Forwarder(self.net, f"client{i}", cs_capacity_bytes=0)
            fc, _ = link(self.net, client, edge, 0.0005)
            client.register_route(DATA, fc)
            self.edges.append(edge)
            self.clients.append(client)
            if policy is not None:
                self.managers.append(
                    ReplicationManager(self.net, edge, policy=policy,
                                       name=f"edge{i}-repl").start())

    def origin_egress(self) -> int:
        return self.fo.tx_data_bytes


def run_workload(plane: WanPlane, *, catalog: int, size: int, reads: int,
                 warmup_reads: int, warmup: float, alpha: float,
                 duration: float, seed: int) -> Dict[str, float]:
    """Seeded zipf read storm in two phases — a warmup that heats both
    planes' locality (Content Stores in the baseline, CS + replicas in
    the armed run), then a fully drained steady-state window where
    goodput and origin egress are measured.  Both runs get the identical
    schedule, so the comparison isolates what proactive placement adds
    over demand caching."""
    rng = random.Random(seed)
    names = []
    for d in range(catalog):
        n = Name.parse(f"/lidc/data/ds{d:03d}/blob")
        plane.lake.put_bytes(n, bytes([d % 251]) * size)
        names.append(n)
    weights = [1.0 / (r + 1) ** alpha for r in range(catalog)]

    done: List[float] = []
    failed: List[str] = []

    def reader(client: Forwarder, name: Name) -> None:
        SegmentFetcher(plane.net, client, name,
                       verify_key=plane.lake.key,
                       on_complete=lambda b: done.append(len(b)),
                       on_error=lambda r: failed.append(r)).start()

    def storm(n_reads: int, over: float) -> int:
        t0 = plane.net.now
        for k in range(n_reads):
            client = plane.clients[k % len(plane.clients)]
            name = rng.choices(names, weights)[0]
            plane.net.schedule(t0 - plane.net.now + over * k / n_reads,
                               lambda c=client, n=name: reader(c, n))
        return n_reads

    # phase 1: warmup (readers heat CS everywhere; managers pull)
    storm(warmup_reads, warmup)
    plane.net.run(until=plane.net.now + warmup)
    plane.net.run()
    warm_egress = plane.origin_egress()
    warm_done, warm_failed = len(done), len(failed)
    done.clear()
    failed.clear()

    # phase 2: the measured steady-state window
    issued = storm(reads, duration)
    t0 = plane.net.now
    plane.net.run(until=t0 + duration)
    plane.net.run()   # drain the tail
    makespan = plane.net.now - t0
    total = float(sum(done))
    return {"issued": issued, "completed": len(done),
            "failed": len(failed), "bytes": total, "makespan": makespan,
            "goodput_mbps": total / makespan / MB if makespan else 0.0,
            "origin_egress": float(plane.origin_egress() - warm_egress),
            "warmup_origin_egress": float(warm_egress),
            "warmup_completed": warm_done, "warmup_failed": warm_failed,
            "warmup_issued": warmup_reads}


def run_scenario(*, engine: str, armed: bool, n_edges: int, catalog: int,
                 size: int, reads: int, warmup_reads: int, warmup: float,
                 segment: int, wan_bw: float,
                 edge_cs_bytes: int, budget: int, duration: float,
                 alpha: float, seed: int, trace: bool = False):
    # hot_rate is calibrated to opener counting: a fully cold read lands
    # up to two demand observations (manifest + seg=0), so 2.4 here keeps
    # the same reader-selectivity a rate of 1.2 had per single-count read
    policy = ReplicationPolicy(hot_rate=2.4, half_life=4 * warmup,
                               interval=0.25, budget_bytes=budget,
                               max_concurrent=2, cooldown=1.0,
                               retry_base=0.25, retry_cap=2.0) if armed \
        else None
    plane = WanPlane(engine=engine, n_edges=n_edges, segment=segment,
                     wan_bw=wan_bw, edge_cs_bytes=edge_cs_bytes,
                     policy=policy)
    if trace:
        plane.net.trace = []
    m = run_workload(plane, catalog=catalog, size=size, reads=reads,
                     warmup_reads=warmup_reads, warmup=warmup,
                     alpha=alpha, duration=duration, seed=seed)
    for mgr in plane.managers:
        st = mgr.stats()
        assert st["max_bytes_used"] <= st["budget_bytes"], \
            f"{mgr.name}: budget exceeded ({st['max_bytes_used']})"
        bad = mgr.audit(plane.lake)
        assert not bad, f"{mgr.name}: stale/corrupt replicas {bad}"
        m[f"{mgr.name}_replicas"] = st["replicas"]
        m[f"{mgr.name}_bytes_served"] = st["bytes_served"]
    m["replica_bytes_served"] = float(sum(
        mgr.stats()["bytes_served"] for mgr in plane.managers))
    m["replication_egress"] = float(sum(
        mgr.stats()["bytes_replicated"] for mgr in plane.managers))
    return plane, m


def bench(*, n_edges: int, catalog: int, size: int, reads: int,
          warmup_reads: int, warmup: float,
          segment: int, wan_bw: float, edge_cs_bytes: int, budget: int,
          duration: float, alpha: float, seed: int) -> Dict[str, float]:
    kw = dict(n_edges=n_edges, catalog=catalog, size=size, reads=reads,
              warmup_reads=warmup_reads, warmup=warmup,
              segment=segment, wan_bw=wan_bw, edge_cs_bytes=edge_cs_bytes,
              budget=budget, duration=duration, alpha=alpha, seed=seed)

    t0 = time.perf_counter()
    _, base = run_scenario(engine="calendar", armed=False, **kw)
    _, repl = run_scenario(engine="calendar", armed=True, **kw)
    wall = time.perf_counter() - t0

    # determinism: the armed run replays identically on both engines
    p1, m1 = run_scenario(engine="calendar", armed=True, trace=True, **kw)
    p2, m2 = run_scenario(engine="heap", armed=True, trace=True, **kw)
    deterministic = (p1.net.trace == p2.net.trace and m1 == m2)

    delivery_base = ((base["completed"] + base["warmup_completed"])
                     / (base["issued"] + base["warmup_issued"]))
    delivery_repl = ((repl["completed"] + repl["warmup_completed"])
                     / (repl["issued"] + repl["warmup_issued"]))
    offload = 1.0 - repl["origin_egress"] / base["origin_egress"]
    # offload including the warmup window, i.e. charging the replication
    # pulls themselves against the savings — the unamortized worst case
    te_base = base["origin_egress"] + base["warmup_origin_egress"]
    te_repl = repl["origin_egress"] + repl["warmup_origin_egress"]
    return {
        "baseline_goodput_mbps": base["goodput_mbps"],
        "replicated_goodput_mbps": repl["goodput_mbps"],
        "goodput_speedup": repl["goodput_mbps"] / base["goodput_mbps"],
        "baseline_origin_egress_mb": base["origin_egress"] / MB,
        "replicated_origin_egress_mb": repl["origin_egress"] / MB,
        "origin_offload": offload,
        "origin_offload_with_warmup": 1.0 - te_repl / te_base,
        "delivery": min(delivery_base, delivery_repl),
        "replica_serve_fraction": repl["replica_bytes_served"]
        / max(repl["bytes"], 1.0),
        "replication_egress_mb": repl["replication_egress"] / MB,
        "deterministic": float(deterministic),
        "wall_seconds": wall,
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--edges", type=int, default=3)
    ap.add_argument("--catalog", type=int, default=24)
    ap.add_argument("--size-kib", type=int, default=1024)
    ap.add_argument("--reads", type=int, default=240)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run, assert the gates, write the "
                         "BENCH_replication.json artifact")
    ap.add_argument("--json", dest="json_path", default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        args.catalog, args.size_kib, args.reads = 16, 256, 240

    size = args.size_kib * 1024
    res = bench(n_edges=args.edges, catalog=args.catalog, size=size,
                reads=args.reads, warmup_reads=args.reads // 2, warmup=6.0,
                segment=32 * 1024, wan_bw=int(4.5 * size),
                edge_cs_bytes=size, budget=14 * size,
                duration=8.0, alpha=0.9, seed=args.seed)

    for k, v in sorted(res.items()):
        print(f"{k:32s} {v:.4f}")

    if args.smoke:
        assert res["deterministic"] == 1.0, "engines diverged"
        assert res["delivery"] == 1.0, f"delivery {res['delivery']}"
        assert res["goodput_speedup"] >= 2.0, \
            f"goodput_speedup {res['goodput_speedup']:.2f} < 2.0"
        assert res["origin_offload"] >= 0.5, \
            f"origin_offload {res['origin_offload']:.2f} < 0.5"
        print("smoke gates passed", file=sys.stderr)

    json_path = args.json_path or ("BENCH_replication.json"
                                   if args.smoke else None)
    if json_path:
        write_bench_json("replication", GATE_METRICS, res, json_path)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
