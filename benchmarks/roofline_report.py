"""Aggregate the dry-run artifacts into the §Roofline table.

Reads artifacts/dryrun/*.json (written by launch/dryrun.py) and emits one
row per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, and the MODEL_FLOPS/HLO_FLOPs utilization ratio.
"""

from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_reports(tag: str = "") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        base = os.path.basename(path)
        if tag:
            if not base.endswith(f"__{tag}.json"):
                continue
        elif base.count("__") > 2:
            continue    # skip tagged variants in the baseline table
        with open(path) as f:
            out.append(json.load(f))
    return out


def run() -> List[Tuple]:
    rows: List[Tuple] = []
    for r in load_reports():
        if r.get("status") != "ok":
            rows.append((f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                         -1.0, "FAILED"))
            continue
        dom_s = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}[r["dominant"]]
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            dom_s * 1e6,                                  # us of dominant term
            f"dom={r['dominant']},ratio={r['useful_ratio']:.3f}"))
    return rows


def markdown_table(tag: str = "") -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s "
             "| dominant | MODEL/HLO | args GB/dev | temp GB/dev |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(load_reports(tag),
                    key=lambda r: (r["arch"], r["shape"], r.get("mesh", ""))):
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} "
                         f"| FAILED | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} "
            f"| {r['useful_ratio']:.3f} | {r['argument_bytes']/1e9:.2f} "
            f"| {r['temp_bytes']/1e9:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
