"""Benchmark harness: one module per paper table/figure.

Each module's ``run()`` returns rows of (name, value, derived); this driver
prints them as ``name,us_per_call,derived`` CSV (value semantics noted per
table: virtual seconds for workflow benches, wall microseconds for step
benches, dominant-term microseconds for roofline rows).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (caching, failover, placement, roofline_report,
                   step_bench, table1_compute)
    modules = [
        ("table1_compute", table1_compute),
        ("placement", placement),
        ("caching", caching),
        ("failover", failover),
        ("step_bench", step_bench),
        ("roofline_report", roofline_report),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                label, value, derived = row
                print(f"{label},{value},{derived}")
        except Exception as e:
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
