"""Failover & straggler mitigation: the decentralized-control-plane claims.

1. kill the serving cluster mid-training-job; measure attempts + total
   virtual time to completion and verify checkpoint resume.
2. straggler mitigation via multicast duplication: completion time equals
   the FAST cluster's, not the slow one's.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core.strategy import MulticastStrategy
from repro.runtime.fleet import build_fleet, resilient_run


def run() -> List[Tuple]:
    rows: List[Tuple] = []

    # --- failover + named-checkpoint resume
    sys_ = build_fleet(n_clusters=2, chips=16, archs=["lidc-demo"],
                       ckpt_every=5)
    fields = {"app": "train", "arch": "lidc-demo", "shape": "custom",
              "chips": 4, "steps": 20, "bench": "failover"}
    killed = {"done": False}
    orig = sys_.lake.put_json

    def hook(name, obj, **kw):
        r = orig(name, obj, **kw)
        if ("ckpt" in str(name) and "latest" in str(name)
                and not killed["done"] and obj.get("step", 0) >= 10):
            killed["done"] = True
            sys_.overlay.fail_cluster(next(iter(sys_.overlay.clusters)))
        return r

    sys_.lake.put_json = hook
    t0 = sys_.net.now
    h, attempts = resilient_run(sys_, fields)
    assert h is not None and h.state == "Completed" and killed["done"]
    resumed = h.result.get("resumed_from") or 0
    rows.append(("failover_resume", sys_.net.now - t0, resumed))
    rows.append(("failover_attempts", attempts, 20))

    # --- straggler mitigation: duplicate to 2, fast one wins
    for strat, label in [(None, "best_route"),
                         (MulticastStrategy(k=2), "multicast2")]:
        sys2 = build_fleet(n_clusters=2, chips=16, archs=["lidc-demo"],
                           ckpt_every=100,
                           latencies=[0.5, 0.001],    # cluster0 is a straggler
                           strategy=strat)
        t0 = sys2.net.now
        h = sys2.client.run_job({"app": "blast", "srr": "SRR2931415",
                                 "db": "human", "mem": 4, "cpu": 2,
                                 "s": label})
        assert h is not None and h.state == "Completed"
        rows.append((f"straggler_{label}", sys2.net.now - t0, 0))
    return rows
