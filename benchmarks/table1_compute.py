"""Paper Table I analog: computation performance vs resource configuration.

The paper BLASTs SRA samples under varying cpu/mem and reports run time +
output size, observing that resource variation barely moves run time.  We
reproduce that table through the LIDC workflow (named Interests, status
polls, result retrieval), then extend it with the ML-era version: a fixed
training job under varying chip grants, where more chips DO help — the
contrast the paper's §VII intelligence needs to learn.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.runtime.fleet import build_fleet


def run() -> List[Tuple]:
    rows: List[Tuple] = []
    sys_ = build_fleet(n_clusters=2, chips=64, archs=["lidc-demo"],
                       ckpt_every=100)

    # --- the paper's own rows (virtual run time from the calibrated model)
    for srr, db, mem, cpu in [
        ("SRR2931415", "human", 4, 2),
        ("SRR2931415", "human", 4, 4),
        ("SRR5139395", "human", 4, 2),
        ("SRR5139395", "human", 6, 2),
    ]:
        h = sys_.client.run_job({"app": "blast", "srr": srr, "db": db,
                                 "mem": mem, "cpu": cpu})
        assert h is not None and h.state == "Completed", (srr, h and h.state)
        rows.append((f"blast_{srr}_mem{mem}_cpu{cpu}",
                     h.result["run_time_s"],
                     h.result["output_bytes"]))

    # --- the ML-era extension: same training job, varying chips
    for chips in [4, 8, 16, 32]:
        h = sys_.client.run_job({"app": "train", "arch": "lidc-demo",
                                 "shape": "custom", "chips": chips,
                                 "steps": 10, "sweep": chips})
        assert h is not None and h.state == "Completed", (chips,
                                                          h and h.state)
        virtual = h.result["step_time_s"] * h.result["steps"]
        rows.append((f"train_lidc-demo_chips{chips}", virtual,
                     h.result["output_bytes"]))
    return rows
