"""The gateway status protocol and rejection taxonomy (paper §IV.A/B).

Covers the previously-untested negative paths — ``unknown-job``,
``malformed-job-name``, ``status-needs-job-id``, validation failures —
plus receipt freshness semantics (Completed receipts are durable result
pointers; Pending/Running receipts go stale fast so a dead cluster's
receipt cannot satisfy a retransmission) and the ``_jobs_by_sig`` dedupe
map's eviction on job completion/failure (the map used to grow forever).
"""

import pytest

from repro.core import reasons
from repro.core.cluster import ComputeCluster, ExecResult
from repro.core.matchmaker import ServiceEndpoint
from repro.core.names import Name
from repro.core.overlay import LidcSystem
from repro.core.packets import Interest, verify_data
from repro.core.validation import ValidationError, ValidatorRegistry


def sim_validators():
    reg = ValidatorRegistry()

    def validate(fields, caps):
        if fields.get("poison"):
            raise ValidationError("poisoned job rejected")

    reg.register("sim", validate)
    return reg


def sim_endpoint(fail_uids=()):
    def executor(job, cluster):
        if job.spec.fields.get("u") in fail_uids:
            raise RuntimeError("synthetic executor failure")
        return ExecResult(payload={"u": job.spec.fields.get("u")},
                          duration=float(job.spec.fields.get("d", 0.5)))

    return ServiceEndpoint(service="sim.lidck8s.svc.cluster.local",
                           app="sim", executor=executor)


@pytest.fixture()
def system():
    sys_ = LidcSystem()
    cluster = ComputeCluster(sys_.net, "pod0", chips=4, lake=sys_.lake,
                             max_queue_depth=4)
    cluster.add_endpoint(sim_endpoint(fail_uids=("boom",)))
    sys_.overlay.add_cluster(cluster, validators=sim_validators())
    sys_.net.run(until=0.2)             # advertisements gossip in
    return sys_


def nack_reason(box):
    assert "error" in box, box
    assert box["error"].startswith(reasons.NACK_PREFIX)
    return box["error"][len(reasons.NACK_PREFIX):]


# ---------------------------------------------------------------------------
# rejection taxonomy
# ---------------------------------------------------------------------------

def test_malformed_job_name_is_rejected(system):
    # /lidc/compute/<app>/<junk>/<junk>/<junk>/... over-deep positional
    # fields cannot be parsed back into a job description
    box = system.client.consumer.get(
        Name.parse("/lidc/compute/sim/a/b/c/d"), retries=0)
    assert nack_reason(box) == reasons.MALFORMED_JOB_NAME
    assert system.overlay.gateways["pod0"].rejections[
        reasons.MALFORMED_JOB_NAME] == 1


def test_validation_failure_travels_back_in_the_nack(system):
    box = system.client.consumer.get(
        Name.parse("/lidc/compute/sim/poison=1"), retries=0)
    reason = nack_reason(box)
    assert reasons.kind_of(reason) == reasons.VALIDATION
    assert "poisoned" in reason
    assert system.overlay.gateways["pod0"].rejections[reasons.VALIDATION] == 1


def test_unknown_application_is_a_validation_reject(system):
    # an unknown app has no advertised route, so ask the gateway directly
    # (a consumer at the cluster node reaches its /lidc/compute producer)
    from repro.core.forwarder import Consumer
    local = Consumer(system.net, system.overlay.clusters["pod0"].node)
    box = local.get(Name.parse("/lidc/compute/unknownapp/x=1"), retries=0)
    reason = nack_reason(box)
    assert reasons.kind_of(reason) == reasons.VALIDATION
    assert "unknown application" in reason


def test_status_needs_job_id(system):
    box = system.client.consumer.get(
        Name.parse("/lidc/status/pod0"), retries=0)
    assert nack_reason(box) == reasons.STATUS_NEEDS_JOB_ID


def test_unknown_job_status(system):
    box = system.client.consumer.get(
        Name.parse("/lidc/status/pod0/no-such-job"), retries=0)
    assert nack_reason(box) == reasons.UNKNOWN_JOB
    assert system.overlay.gateways["pod0"].rejections[reasons.UNKNOWN_JOB] == 1


# ---------------------------------------------------------------------------
# receipt freshness semantics
# ---------------------------------------------------------------------------

def test_running_receipt_is_fast_stale_completed_receipt_durable(system):
    box = {}
    system.client.consumer.express(
        Interest(name=Name.parse("/lidc/compute/sim/chips=1&d=5&u=r1"),
                 must_be_fresh=True, lifetime=4.0),
        on_data=lambda d: box.__setitem__("first", d), retries=0)
    system.net.run(until=0.5)
    first = box["first"]
    assert first.json()["state"] in ("Running", "Pending")
    assert first.freshness == 1.0       # fast-stale: a retransmission after
    #                                     a crash must not see a dead
    #                                     cluster's receipt as live
    assert verify_data(first, b"lidc-gateway-key")
    system.net.run()                    # job completes, result in the lake
    # the same canonical request now shortcuts via the result cache, and
    # the Completed receipt is a durable pointer
    h = system.client.submit({"app": "sim", "chips": 1, "d": 5, "u": "r1"})
    assert h.receipt["state"] == "Completed"
    box2 = system.client.consumer.get(
        Name.parse(h.receipt["status_name"]), retries=0, must_be_fresh=True)
    assert box2["data"].json()["state"] == "Completed"
    assert box2["data"].freshness == 0.25     # status answers stay fresh-only


def test_status_answers_carry_eta_while_pending_or_running(system):
    box = {}
    system.client.consumer.express(
        Interest(name=Name.parse("/lidc/compute/sim/chips=1&d=5&u=eta1"),
                 must_be_fresh=True, lifetime=4.0),
        on_data=lambda d: box.__setitem__("receipt", d), retries=0)
    system.net.run(until=0.5)
    status_name = Name.parse(box["receipt"].json()["status_name"])
    sbox = system.client.consumer.get(status_name, retries=0,
                                      must_be_fresh=True)
    payload = sbox["data"].json()
    assert payload["state"] == "Running"
    assert 0 < payload["eta"] <= 5.1


# ---------------------------------------------------------------------------
# the dedupe map: bounded, evicted on completion AND failure
# ---------------------------------------------------------------------------

def test_jobs_by_sig_evicted_on_completion(system):
    gw = system.overlay.gateways["pod0"]
    for i in range(5):
        h = system.client.run_job({"app": "sim", "chips": 1, "d": 0.1,
                                   "u": f"ok{i}"})
        assert h.state == "Completed"
    # every signature was evicted when its job finished — the map does
    # not grow with completed work (regression: it used to keep every
    # signature forever)
    assert gw._jobs_by_sig == {}


def test_jobs_by_sig_evicted_on_failure_and_resubmission_works(system):
    gw = system.overlay.gateways["pod0"]
    cluster = system.overlay.clusters["pod0"]
    h = system.client.run_job({"app": "sim", "chips": 1, "d": 0.1,
                               "u": "boom"})
    assert h.state == "Failed"
    assert gw._jobs_by_sig == {}        # the failed signature is gone
    jobs_before = len(cluster.jobs)
    # a resubmission of the failed signature spawns a fresh job instead
    # of being shadowed by the stale bookkeeping
    h2 = system.client.run_job({"app": "sim", "chips": 1, "d": 0.1,
                                "u": "boom"})
    assert h2.state == "Failed"
    assert len(cluster.jobs) == jobs_before + 1


def test_inflight_dedupe_still_returns_one_receipt(system):
    """Eviction must not break the live-dedupe path: two expresses of the
    same canonical name while the job runs share one job."""
    boxes = []
    cluster = system.overlay.clusters["pod0"]
    for t in (0.3, 0.6):
        def go(t=t):
            system.client.consumer.express(
                Interest(name=Name.parse(
                    "/lidc/compute/sim/chips=1&d=5&u=dd"),
                    must_be_fresh=True, lifetime=4.0),
                on_data=lambda d: boxes.append(d.json()), retries=0)
        system.net.schedule(t, go)
    system.net.run()
    dd_jobs = [j for j in cluster.jobs.values()
               if j.spec.fields.get("u") == "dd"]
    assert len(dd_jobs) == 1            # the second express deduped
    assert len(boxes) == 2
    assert boxes[0]["job_id"] == boxes[1]["job_id"]
