"""Forwarding plane behaviour: CS hits, NACKs, failover, straggler mitigation."""

from repro.core.forwarder import Consumer, Forwarder, Nack, Network, link
from repro.core.names import Name
from repro.core.packets import Data, Interest
from repro.core.strategy import LoadShareStrategy, MulticastStrategy


def _producer(node, prefix, value=b"v", delay=0.0, fail=False):
    calls = {"n": 0}

    def handler(interest, publish, now):
        calls["n"] += 1
        if fail:
            return Nack(interest, "synthetic")
        d = Data(name=interest.name, content=value, created_at=now,
                 freshness=10.0)
        if delay == 0:
            return d
        node.net.schedule(delay, lambda: publish(d))
        return None

    node.attach_producer(Name.parse(prefix), handler)
    return calls


def _star(n_leaves, latencies=None, strategy=None):
    net = Network()
    hub = Forwarder(net, "hub", strategy=strategy)
    leaves = []
    for i in range(n_leaves):
        leaf = Forwarder(net, f"leaf{i}")
        lat = latencies[i] if latencies else 0.001
        hub_face, _ = link(net, hub, leaf, latency=lat)
        leaves.append((leaf, hub_face))
    return net, hub, leaves


def test_basic_fetch_and_cs_hit():
    net, hub, [(leaf, face)] = _star(1)
    calls = _producer(leaf, "/data")
    hub.register_route(Name.parse("/data"), face)
    c = Consumer(net, hub)
    r1 = c.get(Name.parse("/data/x"))
    assert r1["data"].content == b"v" and calls["n"] == 1
    r2 = c.get(Name.parse("/data/x"))
    assert r2["data"].content == b"v"
    assert calls["n"] == 1          # served from the hub's Content Store
    assert hub.cs.hits >= 1


def test_nack_no_route():
    net, hub, _ = _star(0)
    c = Consumer(net, hub)
    box = c.get(Name.parse("/nowhere/x"), retries=0)
    assert "error" in box and "nack" in box["error"]


def test_nack_failover_to_second_route():
    net, hub, leaves = _star(2)
    (bad, f_bad), (good, f_good) = leaves
    _producer(bad, "/svc", fail=True)
    ok_calls = _producer(good, "/svc")
    hub.register_route(Name.parse("/svc"), f_bad, cost=1.0)   # preferred
    hub.register_route(Name.parse("/svc"), f_good, cost=2.0)
    c = Consumer(net, hub)
    box = c.get(Name.parse("/svc/x"))
    assert box["data"].content == b"v"
    assert ok_calls["n"] == 1


def test_dead_cluster_failover_via_retransmission():
    net, hub, leaves = _star(2)
    (dead, f_dead), (alive, f_alive) = leaves
    _producer(dead, "/svc")
    alive_calls = _producer(alive, "/svc")
    hub.register_route(Name.parse("/svc"), f_dead, cost=1.0)
    hub.register_route(Name.parse("/svc"), f_alive, cost=2.0)
    f_dead.down = True              # cluster goes dark: packets vanish
    c = Consumer(net, hub)
    box = c.get(Name.parse("/svc/x"))
    # the first interest times out; retransmission tries the next route
    assert box.get("data") is not None
    assert alive_calls["n"] == 1


def test_multicast_first_answer_wins_and_dedupes():
    net, hub, leaves = _star(2, latencies=[0.05, 0.001],
                             strategy=MulticastStrategy(k=2))
    (slow, f_slow), (fast, f_fast) = leaves
    _producer(slow, "/svc", value=b"slow", delay=1.0)
    _producer(fast, "/svc", value=b"fast", delay=0.0)
    hub.register_route(Name.parse("/svc"), f_slow, cost=1.0)
    hub.register_route(Name.parse("/svc"), f_fast, cost=1.0)
    c = Consumer(net, hub)
    got = []
    c.express(Interest(name=Name.parse("/svc/x")), on_data=got.append)
    net.run()
    assert len(got) == 1            # duplicate answer suppressed by PIT/CS
    assert got[0].content == b"fast"


def test_loadshare_distributes():
    net, hub, leaves = _star(2, strategy=LoadShareStrategy())
    calls = []
    for leaf, face in leaves:
        calls.append(_producer(leaf, "/svc"))
        hub.register_route(Name.parse("/svc"), face, cost=1.0)
    c = Consumer(net, hub)
    for i in range(10):
        c.get(Name.parse(f"/svc/{i}"))
    assert calls[0]["n"] > 0 and calls[1]["n"] > 0
    assert calls[0]["n"] + calls[1]["n"] == 10


def test_hop_limit_drops():
    net = Network()
    a = Forwarder(net, "a")
    b = Forwarder(net, "b")
    fa, fb = link(net, a, b)
    # route loop: a -> b and b -> a for the same prefix
    a.register_route(Name.parse("/loop"), fa)
    b.register_route(Name.parse("/loop"), fb)
    c = Consumer(net, a)
    box = c.get(Name.parse("/loop/x"), retries=0, hop_limit=8)
    net.run()
    assert "data" not in box        # died by hop limit / nonce suppression
