"""The dry-run CLI end to end (subprocess: it must own jax device init).

One cheap cell on the full 512-device production meshes proves the
pipeline: mesh build -> shardings -> lower -> compile -> roofline artifact.
"""

import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dryrun_single_cell(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)   # dryrun.py sets its own
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen2-0.5b", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=540, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "[OK]" in out.stdout
    art = tmp_path / "qwen2-0.5b__decode_32k__single.json"
    assert art.exists()
    r = json.loads(art.read_text())
    assert r["status"] == "ok"
    assert r["chips"] == 256
    assert r["compute_s"] > 0 or r["memory_s"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert r["argument_bytes"] > 0


def test_dryrun_list():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--list"],
        capture_output=True, text=True, timeout=120, env=env, cwd=ROOT)
    assert out.returncode == 0
    lines = [l for l in out.stdout.splitlines() if l.strip()]
    # 10 archs x 3 shapes + 2 long_500k cells = 32
    assert len(lines) == 32
    assert sum("long_500k" in l for l in lines) == 2
