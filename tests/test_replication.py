"""Demand-driven replication plane: policy, durability, determinism.

Covers the replication invariants the benchmark gates at scale:

* no replication storms — one hot object yields exactly one transfer per
  manager and total origin egress bounded by replica_count x size + eps;
* the byte budget is never exceeded at any instant (``max_bytes_used``),
  with cold-first eviction making room for hotter objects;
* a transfer that dies mid-flight (node blackout) resumes from the
  segments it already persisted, not from zero;
* a partition parks failed pulls in the durable retry queue; healing
  drains it and the replica installs;
* the whole plane is replay-deterministic on both event engines;
* the DemandTracker stays bounded under 10k-name churn.
"""

import pytest

from repro.core import Forwarder, Name, Network
from repro.core.demand import DemandTracker
from repro.core.forwarder import link
from repro.core.routing import capability_cost
from repro.datalake import (DataLake, ReplicationManager,
                            ReplicationPolicy, fetch)
from repro.workflow.faults import FaultInjector

DATA = Name.parse("/lidc/data")


class Plane:
    """client -- edge -- origin over a slow WAN hop; manager on the edge."""

    def __init__(self, *, engine: str = "calendar", segment: int = 4096,
                 wan_latency: float = 0.02, edge_cs_bytes: int = 1 << 20,
                 policy: ReplicationPolicy = None):
        self.net = Network(engine=engine)
        self.origin = Forwarder(self.net, "origin")
        self.edge = Forwarder(self.net, "edge",
                              cs_capacity_bytes=edge_cs_bytes)
        self.client = Forwarder(self.net, "client", cs_capacity_bytes=4096)
        self.fe, self.fo = link(self.net, self.edge, self.origin, wan_latency)
        fc, _ = link(self.net, self.client, self.edge, 0.001)
        self.edge.register_route(DATA, self.fe)
        self.client.register_route(DATA, fc)
        self.lake = DataLake(segment_size=segment)
        self.lake.attach(self.origin)
        self.policy = policy or ReplicationPolicy(
            hot_rate=3.0, budget_bytes=1 << 20, interval=0.25,
            retry_base=0.25, retry_cap=1.0)
        self.mgr = ReplicationManager(self.net, self.edge,
                                      policy=self.policy).start()

    def publish(self, name: str, nbytes: int, fill: int = 7) -> Name:
        n = Name.parse(name)
        self.lake.put_bytes(n, bytes([fill]) * nbytes)
        return n

    def heat(self, name: Name, t: float = 0.0, times: int = 4) -> None:
        """Synthetic reader demand, no data traffic behind it."""
        for _ in range(times):
            self.mgr.demand.observe(name, t)


def test_hot_object_replicated_served_and_audited():
    p = Plane()
    name = p.publish("/lidc/data/ds0/blob", 40960)
    p.heat(name)
    p.net.run(until=10.0)
    st = p.mgr.stats()
    assert st["replicas"] == 1 and st["transfers_completed"] == 1
    assert p.mgr.audit(p.lake) == []          # byte-identical to the origin
    assert name.components in p.edge._producers   # served, not just cached
    # a post-replication read is satisfied locally: zero origin egress
    tx0 = p.fo.tx_data_bytes
    f = fetch(p.net, p.client, name, verify_key=p.lake.key)
    p.net.run()
    assert f.result == bytes([7]) * 40960
    assert p.fo.tx_data_bytes == tx0
    assert p.mgr.serves > 0 or p.edge.stats["cs_hit"] > 0


def test_no_replication_storm_bounded_egress():
    # demand stays hot across many ticks; still exactly one transfer,
    # and origin egress is bounded by one copy of the object (+manifest)
    p = Plane()
    name = p.publish("/lidc/data/ds0/blob", 65536)
    for t in range(8):
        p.heat(name, t=0.1 * t)
    p.net.run(until=15.0)
    st = p.mgr.stats()
    assert st["transfers_started"] == 1
    assert st["replicas"] == 1
    assert p.fo.tx_data_bytes <= 65536 * 1.05 + 4096


def test_budget_never_exceeded_cold_first_eviction():
    size = 32768
    pol = ReplicationPolicy(hot_rate=3.0, interval=0.25, cooldown=0.5,
                            budget_bytes=int(2.5 * size))
    p = Plane(policy=pol)
    names = [p.publish(f"/lidc/data/ds{i}/blob", size, fill=i) for i in range(4)]
    # heat the four objects in sequence: the budget fits only two, so the
    # coldest must give way as hotter arrivals need room
    for i, n in enumerate(names):
        p.net.schedule(2.0 * i, lambda n=n: p.heat(n, p.net.now, times=6))
    p.net.run(until=20.0)
    st = p.mgr.stats()
    assert st["max_bytes_used"] <= pol.budget_bytes    # never, at any instant
    assert st["evictions"] >= 1
    assert st["transfers_completed"] >= 3
    assert p.mgr.audit(p.lake) == []
    # evicted replicas are de-registered: no stale local producers
    assert len(p.edge._producers) == st["replicas"]


def test_crash_mid_transfer_resumes_from_persisted_segments():
    p = Plane(segment=1024)
    name = p.publish("/lidc/data/ds0/blob", 65536)   # 64 segments
    p.heat(name)
    inj = FaultInjector(p.net, seed=1)
    # transfer starts at the 0.25s tick; go dark mid-flight, heal later.
    # the blackout flag doubles as the manager's liveness: while dark the
    # tick parks and the retry queue waits on the clock.
    box = inj.blackout([p.fe, p.fo], at=0.4, heal_at=3.0)
    p.mgr.alive = lambda: box[0]
    p.net.run(until=30.0)
    st = p.mgr.stats()
    assert st["replicas"] == 1 and st["transfers_completed"] == 1
    assert st["retries"] >= 1
    assert st["segments_resumed"] >= 1     # did NOT restart from zero
    assert st["segments_resumed"] < 64     # ... and had something to fetch
    assert p.mgr.audit(p.lake) == []


def test_partition_heal_drains_retry_queue():
    p = Plane(segment=1024)
    name = p.publish("/lidc/data/ds0/blob", 32768)
    p.heat(name)
    inj = FaultInjector(p.net, seed=1)
    inj.blackout([p.fe, p.fo], at=0.3, heal_at=6.0)   # WAN partition only:
    # the manager stays alive, so failed pulls queue and back off
    queue_seen = []

    def probe():
        queue_seen.append(p.mgr.stats()["retry_queue"]
                          + p.mgr.stats()["in_flight"])
        if p.net.now < 5.5:
            p.net.schedule(0.5, probe, daemon=True)

    p.net.schedule(2.0, probe, daemon=True)
    p.net.run(until=30.0)
    assert max(queue_seen) >= 1            # the pull was parked, not lost
    st = p.mgr.stats()
    assert st["replicas"] == 1             # ... and drained after heal
    assert st["retry_queue"] == 0
    assert p.mgr.audit(p.lake) == []


def _churn_scenario(engine: str):
    p = Plane(engine=engine, segment=1024)
    names = [p.publish(f"/lidc/data/ds{i}/blob", 16384, fill=i)
             for i in range(3)]
    for i, n in enumerate(names):
        p.net.schedule(0.5 * i, lambda n=n: p.heat(n, p.net.now, times=5))
    inj = FaultInjector(p.net, seed=3)
    box = inj.churn([p.fe, p.fo], period=2.0, down=0.8, start=0.6, stop=6.0)
    p.mgr.alive = lambda: box[0]
    p.net.trace = []
    p.net.run(until=40.0)
    return p.net.trace, p.net.now, p.mgr.stats(), p.mgr.audit(p.lake)


def test_replay_deterministic_across_engines_under_churn():
    heap = _churn_scenario("heap")
    cal = _churn_scenario("calendar")
    assert heap == cal
    trace, _, st, bad = cal
    assert len(trace) > 100
    assert st["replicas"] == 3 and bad == []


def test_demand_tracker_bounded_under_name_churn():
    d = DemandTracker(capacity=256, half_life=2.0)
    for i in range(10_000):
        d.observe(Name.parse(f"/lidc/data/ds{i}/blob"), now=i * 0.001)
    assert len(d) <= 256
    st = d.stats()
    assert st["evictions"] == 10_000 - 256
    assert st["observations"] == 10_000
    # non-data names and bare prefix are not tracked at all
    d2 = DemandTracker(capacity=8)
    d2.observe(Name.parse("/lidc/compute/job1"), now=0.0)
    d2.observe(Name.parse("/lidc/data"), now=0.0)
    assert len(d2) == 0


def test_demand_tracker_decay_segments_and_ignore_faces():
    d = DemandTracker(capacity=8, half_life=1.0)
    base = Name.parse("/lidc/data/ds0/blob")
    # demand counts READS: the opener Interests of a windowed fetch
    # (manifest, seg=0) count toward the base object; the later segment
    # Interests are the same read and count nothing.  Counting both
    # openers keeps the signal alive when a downstream cache absorbs
    # one of them (a reader holding just the tiny manifest would
    # otherwise hide every repeat read of the hottest object).
    for _ in range(5):
        d.observe(base.append("manifest"), now=0.0)
    d.observe(base.append("seg=0"), now=0.0)
    for i in range(1, 5):
        d.observe(base.append(f"seg={i}"), now=0.0)
    assert len(d) == 1
    assert d.rate(base, now=0.0) == pytest.approx(6.0)
    assert d.rate(base, now=1.0) == pytest.approx(3.0)   # one half-life
    assert d.hot(0.0, threshold=3.0) == [(base.components, 6.0)]
    assert d.hot(10.0, threshold=3.0) == []
    # a manager's own transfer face never reads as reader demand
    d.ignore_faces.add(99)
    d.observe(base, now=0.0, in_face=99)
    assert d.rate(base, now=0.0) == pytest.approx(6.0)


def test_demand_tracker_excludes_derived_namespaces():
    # compute results and live serving-session state are owned by their
    # planes: proactively replicating them races stage retries
    # (exactly-once) or serves stale session tokens — never candidates
    d = DemandTracker(capacity=8,
                      exclude=("/lidc/data/results", "/lidc/data/serve"))
    for _ in range(5):
        d.observe(Name.parse("/lidc/data/results/abcd1234"), now=0.0)
        d.observe(Name.parse("/lidc/data/serve/sess/s0/chunk=0"), now=0.0)
    assert len(d) == 0
    d.observe(Name.parse("/lidc/data/ds0/blob"), now=0.0)
    assert len(d) == 1
    # the manager wires the policy's exclusions straight through
    net = Network()
    mgr = ReplicationManager(net, Forwarder(net, "n"))
    assert mgr.demand.exclude_keys == (
        ("lidc", "data", "results"), ("lidc", "data", "serve"))


def test_replica_caps_rank_as_pure_hop_cost():
    assert capability_cost({"replica": "edge-repl"}) == 0.0
    assert capability_cost({}) == 0.0
    assert capability_cost(None) == 0.0


def test_replica_advertised_via_gossip_steers_readers():
    # ring 0-1-2-3-4: origin lake at node 0; manager on node 2.  After the
    # pull, node 2 originates the object name through routing gossip with
    # replica caps; node 3's FIB must then prefer its 1-hop neighbor 2
    # (longest-prefix route) over the 2-hop path to the origin.
    from repro.core.overlay import MeshTopology

    net = Network()
    mesh = MeshTopology(net, 5, "ring", seed=2)
    lake = DataLake(segment_size=2048)
    lake.attach(mesh.nodes[0])
    mesh.agents[0].originate(DATA)
    mesh.converge(timeout=20.0)

    name = Name.parse("/lidc/data/ds0/blob")
    lake.put_bytes(name, b"\5" * 16384)
    mgr = ReplicationManager(net, mesh.nodes[2], agent=mesh.agents[2],
                             policy=ReplicationPolicy(hot_rate=3.0,
                                                      budget_bytes=1 << 20)
                             ).start()
    for _ in range(4):
        mgr.demand.observe(name, net.now)
    net.run(until=30.0)
    assert mgr.stats()["replicas"] == 1

    prefix, hops = mesh.nodes[3].fib.lookup(name)
    assert prefix is not None
    assert len(prefix.components) > len(DATA.components)   # replica route
    toward_replica = mesh.faces[(3, 2)].face_id
    assert [h.face_id for h in hops] == [toward_replica]

    tx0 = sum(f.tx_data_bytes for (i, _), f in mesh.faces.items() if i == 0)
    f = fetch(net, mesh.nodes[3], name, verify_key=lake.key)
    net.run()
    assert f.result == b"\5" * 16384
    tx1 = sum(f.tx_data_bytes for (i, _), f in mesh.faces.items() if i == 0)
    assert tx1 == tx0                      # the origin never saw the read

    # eviction withdraws the advertisement: the route must disappear.
    # stop the policy first — the read above re-heated demand at node 2,
    # and a live manager would (correctly) just re-replicate.
    mgr.stop()
    mgr._evict(name.components)
    net.run(until=net.now + 15.0)
    prefix2, _ = mesh.nodes[3].fib.lookup(name)
    assert prefix2 is None or len(prefix2.components) == len(DATA.components)
