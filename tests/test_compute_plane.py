"""The cluster scheduler: priorities, preemption, backfill/aging, ETA, spill.

All scenarios run on the deterministic virtual clock; start/finish times
are asserted exactly.  The equivalence tests at the bottom are the
acceptance property for the refactor: with preemption and spill disabled,
the legacy ``no-capacity`` Nack path (``legacy_nack=True``) and the new
busy-receipt path admit, start and complete the *same* jobs at the *same*
virtual times — the busy receipt only changes what a rejected client
learns.
"""

import random

import pytest

from repro.core import reasons
from repro.core.cluster import ComputeCluster, ExecPlan, ExecResult
from repro.core.compute_plane import LOCAL_FACE, SchedulerConfig
from repro.core.forwarder import Network
from repro.core.jobs import JobSpec
from repro.core.matchmaker import ServiceEndpoint
from repro.core.names import canonical_job_name
from repro.core.overlay import LidcClient, LidcSystem
from repro.core.packets import Interest
from repro.core.validation import ValidatorRegistry


# ---------------------------------------------------------------------------
# a tiny simulated application: fields drive duration/phases, a shared log
# records exactly which (job, phase) work actually executed
# ---------------------------------------------------------------------------

def sim_executor(log):
    def executor(job, cluster):
        fields = job.spec.fields
        dur = float(fields.get("d", 1))
        phases = int(fields.get("phases", 0))
        uid = fields.get("u", job.job_id)
        if phases <= 0:
            log.append((uid, "run", cluster.name))
            return ExecResult(payload={"u": uid}, duration=dur)

        def phase_fn(i):
            def work():
                log.append((uid, f"phase{i}", cluster.name))
            return work

        return ExecPlan(
            phases=[(dur / phases, phase_fn(i)) for i in range(phases)],
            finalize=lambda: ExecResult(payload={"u": uid}, duration=0.0))

    return executor


def sim_endpoint(log, *, max_chips=1 << 20):
    return ServiceEndpoint(service="sim.lidck8s.svc.cluster.local",
                           app="sim", max_chips=max_chips,
                           executor=sim_executor(log))


def sim_validators():
    reg = ValidatorRegistry()
    reg.register("sim", lambda fields, caps: None)
    return reg


def make_cluster(net, log, *, chips=8, max_queue_depth=8, config=None):
    cluster = ComputeCluster(net, "c0", chips=chips,
                             max_queue_depth=max_queue_depth,
                             scheduler_config=config)
    cluster.add_endpoint(sim_endpoint(log))
    return cluster


def spec(uid, *, chips=1, d=1.0, prio=0, phases=0):
    fields = {"chips": chips, "d": d, "u": uid}
    if prio:
        fields["prio"] = prio
    if phases:
        fields["phases"] = phases
    return JobSpec(app="sim", fields=fields)


# ---------------------------------------------------------------------------
# dispatch order, backfill, aging
# ---------------------------------------------------------------------------

def test_priority_order_beats_fifo():
    net, log = Network(), []
    cluster = make_cluster(net, log, chips=4)
    cluster.submit(spec("running", chips=4, d=2.0), now=0.0)
    low = cluster.submit(spec("low", chips=4, d=1.0), now=0.0)
    high = cluster.submit(spec("high", chips=4, d=1.0, prio=5), now=0.0)
    net.run()
    assert high.started_at == 2.0       # outranked the earlier-queued job
    assert low.started_at == 3.0
    assert low.state.value == high.state.value == "Completed"


def test_backfill_starts_small_jobs_around_blocked_head():
    net, log = Network(), []
    cluster = make_cluster(net, log, chips=8,
                           config=SchedulerConfig(starvation_age=100.0))
    cluster.submit(spec("wide0", chips=6, d=3.0), now=0.0)
    big = cluster.submit(spec("big", chips=8, d=1.0), now=0.0)   # blocked
    small = cluster.submit(spec("small", chips=2, d=0.5), now=0.0)
    net.run()
    assert small.started_at == 0.0      # backfilled around the blocked head
    assert big.started_at == 3.0        # ran when the wide job released
    assert cluster.scheduler.stats["backfills"] >= 1


def test_aged_head_blocks_backfill_so_large_grants_never_starve():
    net, log = Network(), []
    cluster = make_cluster(
        net, log, chips=8,
        config=SchedulerConfig(starvation_age=1.0, aging_rate=0.0))
    cluster.submit(spec("wide0", chips=6, d=3.0), now=0.0)
    big = cluster.submit(spec("big", chips=8, d=1.0), now=0.0)   # blocked
    young = cluster.submit(spec("young", chips=2, d=0.2), now=0.0)
    late = {"job": None}

    def submit_late():
        # arrives after the head aged past starvation_age: must NOT
        # backfill even though 2 chips are free — the head reserves them
        late["job"] = cluster.submit(spec("late", chips=2, d=0.2),
                                     now=net.now)

    net.schedule(2.0, submit_late)
    net.run()
    assert young.started_at == 0.0          # backfill while the head is young
    assert big.started_at == 3.0            # the reservation held
    assert late["job"].started_at >= big.started_at
    assert late["job"].state.value == "Completed"


def test_low_priority_ages_past_fresh_high_priority_arrivals():
    net, log = Network(), []
    cluster = make_cluster(
        net, log, chips=4,
        config=SchedulerConfig(aging_rate=1.0, starvation_age=1e9))
    cluster.submit(spec("seed", chips=4, d=1.0), now=0.0)
    low = cluster.submit(spec("batch", chips=4, d=1.0, prio=0), now=0.0)

    def submit_urgent(uid):
        cluster.submit(spec(uid, chips=4, d=1.0, prio=2), now=net.now)

    # a fresh urgent job lands just before every completion boundary
    for i in range(4):
        net.schedule(0.5 + i, lambda i=i: submit_urgent(f"urgent{i}"))
    net.run()
    # with aging_rate=1, the batch job's effective priority (0 + waited
    # seconds) passes the urgent class (2 + small waits) by t=3 — it runs
    # ahead of the urgent2/urgent3 arrivals instead of starving
    assert low.state.value == "Completed"
    assert low.started_at == 3.0
    urgent2 = next(j for j in cluster.jobs.values()
                   if j.spec.fields["u"] == "urgent2")
    assert urgent2.started_at > low.started_at


# ---------------------------------------------------------------------------
# preemption at phase boundaries
# ---------------------------------------------------------------------------

def test_preemption_releases_at_phase_boundary_and_resumes_locally():
    net, log = Network(), []
    cluster = make_cluster(net, log, chips=8)
    victim = cluster.submit(spec("victim", chips=8, d=4.0, phases=4), now=0.0)
    urgent = {"job": None}

    def submit_urgent():
        # chips=4 (not 8): a distinct CompletionModel job key, so the
        # learned-duration assertion below sees only the victim's EWMA
        urgent["job"] = cluster.submit(
            spec("urgent", chips=4, d=1.0, prio=5), now=net.now)

    net.schedule(0.5, submit_urgent)
    net.run()
    # the victim released at the t=1.0 phase boundary, not immediately
    assert urgent["job"].started_at == 1.0
    assert urgent["job"].finished_at == 2.0
    # ...and resumed at t=2.0 with phases 1-3 (no re-execution of phase 0)
    assert victim.state.value == "Completed"
    assert victim.preemptions == 1
    assert victim.finished_at == 5.0
    phase_runs = [e for e in log if e[0] == "victim"]
    assert phase_runs == [("victim", f"phase{i}", "c0") for i in range(4)]
    assert cluster.scheduler.stats["preemptions"] == 1
    assert cluster.scheduler.stats["resumes"] == 1
    # the completion model learned the victim's TOTAL on-chip time (4s),
    # not just the post-resume segment (3s)
    est = cluster.scheduler.run_estimate(
        spec("victim", chips=8, d=4.0, phases=4))
    assert est == pytest.approx(4.0)


def test_preemption_disabled_leaves_running_jobs_alone():
    net, log = Network(), []
    cluster = make_cluster(net, log, chips=8,
                           config=SchedulerConfig(preemption=False))
    victim = cluster.submit(spec("victim", chips=8, d=4.0, phases=4), now=0.0)
    urgent = cluster.submit(spec("urgent", chips=8, d=1.0, prio=5), now=0.0)
    net.run()
    assert victim.preemptions == 0
    assert urgent.started_at == 4.0     # waited for the full run
    assert cluster.scheduler.stats["preemptions"] == 0


def test_equal_priorities_never_preempt():
    net, log = Network(), []
    cluster = make_cluster(net, log, chips=8)
    a = cluster.submit(spec("a", chips=8, d=2.0, phases=2), now=0.0)
    b = cluster.submit(spec("b", chips=8, d=1.0), now=0.0)
    net.run()
    assert a.preemptions == 0
    assert b.started_at == 2.0


# ---------------------------------------------------------------------------
# ETA
# ---------------------------------------------------------------------------

def test_eta_accounts_for_running_and_queued_work():
    net, log = Network(), []
    cluster = make_cluster(net, log, chips=4,
                           config=SchedulerConfig(default_run_estimate=1.0))
    cluster.submit(spec("r", chips=4, d=2.0), now=0.0)
    queued = cluster.submit(spec("q", chips=4, d=1.0), now=0.0)
    sched = cluster.scheduler
    # queued job: starts when the runner releases (t=2), prior estimate 1s
    assert sched.eta_of(queued.job_id) == pytest.approx(3.0)
    # a hypothetical new arrival queues behind it
    assert sched.eta(spec("new", chips=4)) == pytest.approx(4.0)
    assert sched.eta_p50() == pytest.approx(3.0)
    net.run()
    assert sched.eta_p50() == 0.0       # drained


def test_eta_learns_from_observed_run_times():
    net, log = Network(), []
    cluster = make_cluster(net, log, chips=4)
    s = spec("learn", chips=4, d=2.5)
    cluster.submit(s, now=0.0)
    net.run()
    # the completion fed the model under the cluster's local face
    est = cluster.scheduler.run_estimate(s)
    assert est == pytest.approx(2.5, rel=1e-6)
    pred = cluster.scheduler.model.predict(
        {"app": "sim", **s.fields}, face_id=LOCAL_FACE)
    assert pred == pytest.approx(2.5, rel=1e-6)


def test_capability_record_carries_eta_p50_and_caches():
    net, log = Network(), []
    cluster = make_cluster(net, log, chips=4)
    rec1 = cluster.capability_record()
    assert rec1["eta_p50"] == 0.0
    assert cluster.capability_record() is rec1      # cached, same dict
    cluster.submit(spec("r", chips=4, d=2.0), now=0.0)
    cluster.submit(spec("q", chips=4, d=1.0), now=0.0)
    rec2 = cluster.capability_record()
    assert rec2 is not rec1                         # invalidated by load
    assert rec2["queue_depth"] == 1
    assert rec2["eta_p50"] == pytest.approx(3.0)


def test_load_triggered_readvertisement_is_damped():
    net, log = Network(), []
    cluster = make_cluster(
        net, log, chips=4,
        config=SchedulerConfig(readvertise_min_interval=0.5,
                               readvertise_factor=2.0))
    calls = []
    cluster.on_caps_changed = lambda: calls.append(net.now)
    # a burst of admissions at t in [0.6, 0.605, ...]: saturation flips and
    # queues build, but the damping interval bounds the re-advertisements
    for i in range(6):
        net.schedule(0.6 + i * 0.001,
                     lambda i=i: cluster.submit(
                         spec(f"j{i}", chips=4, d=5.0), now=net.now))
    net.run(until=1.0)
    assert 1 <= len(calls) <= 2         # not one advert per admission
    net.run(until=60.0)
    # drain is also a significant swing -> at least one more re-advert
    assert len(calls) >= 2
    assert all(b - a >= 0.5 for a, b in zip(calls, calls[1:]))


# ---------------------------------------------------------------------------
# busy receipts + the legacy flag (system level, through the overlay)
# ---------------------------------------------------------------------------

def build_system(n=1, *, chips=4, max_queue_depth=0, config=None,
                 legacy_nack=False, log=None):
    sys_ = LidcSystem()
    log = log if log is not None else []
    for i in range(n):
        cluster = ComputeCluster(sys_.net, f"pod{i}", chips=chips,
                                 lake=sys_.lake,
                                 max_queue_depth=max_queue_depth,
                                 scheduler_config=config)
        cluster.add_endpoint(sim_endpoint(log))
        sys_.overlay.add_cluster(cluster, validators=sim_validators(),
                                 legacy_nack=legacy_nack)
    sys_.net.run(until=0.2)             # let the advertisements gossip
    return sys_, log


def express_at(sys_, consumer, t, fields, outcomes, uid, retries=0):
    """Schedule a compute Interest at virtual time ``t`` (so long-running
    jobs cannot complete between submissions the way back-to-back
    ``client.submit`` calls — each a full ``net.run()`` — would allow)."""
    def submit():
        consumer.express(
            Interest(name=canonical_job_name(fields),
                     lifetime=2.0, must_be_fresh=True),
            on_data=lambda d: outcomes.__setitem__(uid, ("receipt", d)),
            on_fail=lambda r: outcomes.__setitem__(uid, ("fail", r)),
            retries=retries)
    sys_.net.schedule(max(0.0, t - sys_.net.now), submit)


def test_saturated_gateway_answers_busy_receipt_with_eta():
    sys_, log = build_system()
    out = {}
    c = sys_.client.consumer
    express_at(sys_, c, 0.3, {"app": "sim", "chips": 4, "d": 60, "u": "a"},
               out, "a")
    express_at(sys_, c, 0.4, {"app": "sim", "chips": 4, "d": 1, "u": "b"},
               out, "b")
    sys_.net.run()
    assert out["a"][0] == "receipt"
    assert out["b"][0] == "fail" and reasons.is_busy_failure(out["b"][1])
    nack = sys_.client.consumer.nacks[-1]
    assert reasons.kind_of(nack.reason) == reasons.BUSY
    assert nack.info is not None and nack.info["eta"] > 0
    assert nack.info["free_chips"] == 0
    gw = sys_.overlay.gateways["pod0"]
    assert gw.busy_receipts == 1


def test_legacy_flag_restores_bare_no_capacity_nack():
    sys_, log = build_system(legacy_nack=True)
    out = {}
    c = sys_.client.consumer
    express_at(sys_, c, 0.3, {"app": "sim", "chips": 4, "d": 60, "u": "a"},
               out, "a")
    express_at(sys_, c, 0.4, {"app": "sim", "chips": 4, "d": 1, "u": "b"},
               out, "b")
    sys_.net.run()
    assert out["b"][0] == "fail"
    nack = sys_.client.consumer.nacks[-1]
    assert reasons.kind_of(nack.reason) == reasons.NO_CAPACITY
    assert nack.info is None


def test_pending_receipt_carries_eta():
    sys_, log = build_system(max_queue_depth=4)
    out = {}
    c = sys_.client.consumer
    express_at(sys_, c, 0.3, {"app": "sim", "chips": 4, "d": 10, "u": "a"},
               out, "a")
    express_at(sys_, c, 0.4, {"app": "sim", "chips": 4, "d": 1, "u": "b"},
               out, "b")
    sys_.net.run()
    assert out["b"][0] == "receipt"
    receipt = out["b"][1].json()
    assert receipt["state"] == "Pending"
    assert receipt["eta"] > 0


# ---------------------------------------------------------------------------
# decentralized spill
# ---------------------------------------------------------------------------

def spill_config(**kw):
    return SchedulerConfig(spill_queue_depth=0, **kw)


def test_saturated_cluster_spills_to_peer_in_band():
    sys_ = LidcSystem()
    log = []
    spiller = ComputeCluster(sys_.net, "hot", chips=4, lake=sys_.lake,
                             max_queue_depth=8,
                             scheduler_config=spill_config())
    spiller.add_endpoint(sim_endpoint(log))
    peer = ComputeCluster(sys_.net, "cold", chips=4, lake=sys_.lake,
                          max_queue_depth=8)
    peer.add_endpoint(sim_endpoint(log))
    sys_.overlay.add_cluster(spiller, validators=sim_validators())
    sys_.overlay.add_cluster(peer, validators=sim_validators())
    sys_.net.run(until=0.2)
    # a client attached *at the hot cluster's node*: its gateway producer
    # answers first, so every job lands on "hot" regardless of strategy
    client = LidcClient(sys_.net, spiller.node, name="local-client")
    out = {}
    express_at(sys_, client.consumer, 0.3,
               {"app": "sim", "chips": 4, "d": 30, "u": "fill"}, out, "fill")
    express_at(sys_, client.consumer, 0.4,
               {"app": "sim", "chips": 4, "d": 1, "u": "shed"}, out, "shed",
               retries=2)
    sys_.net.run()
    assert out["fill"][1].json()["cluster"] == "hot"
    # the hot gateway re-expressed the Interest upstream; the peer's
    # receipt came back under the original name
    assert out["shed"][0] == "receipt"
    receipt = out["shed"][1].json()
    assert receipt["cluster"] == "cold"
    assert receipt["spilled_via"] == "hot"
    gw = sys_.overlay.gateways["hot"]
    assert gw.spills == 1
    assert ("shed", "run", "cold") in log       # executed on the peer
    # the spilled request kept the canonical result name (spill= is
    # transport metadata, not work identity)
    s = JobSpec(app="sim", fields={"chips": 4, "d": 1, "u": "shed"})
    from repro.core.jobs import result_name_for
    assert receipt["result_name"] == str(result_name_for(s))


def test_spill_loop_is_suppressed_by_hop_carried_path():
    net, log = Network(), []
    cluster = make_cluster(net, log, chips=4, config=spill_config())
    from repro.core.gateway import Gateway
    gw = Gateway(cluster, validators=sim_validators())
    # an Interest whose spill path already contains this cluster must be
    # answered busy (with an ETA), never re-shed or executed in a circle
    name = canonical_job_name({"app": "sim", "chips": 4, "u": "x",
                               "spill": "other:c0"})
    out = gw._on_compute(Interest(name=name), publish=lambda d: None,
                         now=0.0)
    from repro.core.forwarder import Nack
    assert isinstance(out, Nack)
    assert reasons.is_busy_failure(out.reason)
    assert out.info is not None and "eta" in out.info
    assert gw.spills == 0


def test_spill_fallback_admits_locally_when_no_peer_answers():
    # one lonely saturated cluster with spill enabled: the re-expression
    # finds no route, and the gateway falls back to queued admission
    sys_ = LidcSystem()
    log = []
    cluster = ComputeCluster(sys_.net, "solo", chips=4, lake=sys_.lake,
                             max_queue_depth=8,
                             scheduler_config=spill_config())
    cluster.add_endpoint(sim_endpoint(log))
    sys_.overlay.add_cluster(cluster, validators=sim_validators())
    sys_.net.run(until=0.2)
    client = LidcClient(sys_.net, cluster.node, name="local-client")
    out = {}
    express_at(sys_, client.consumer, 0.3,
               {"app": "sim", "chips": 4, "d": 3, "u": "fill"}, out, "fill")
    express_at(sys_, client.consumer, 0.4,
               {"app": "sim", "chips": 4, "d": 1, "u": "fb"}, out, "fb",
               retries=3)
    sys_.net.run()
    assert out["fb"][0] == "receipt"
    assert out["fb"][1].json()["cluster"] == "solo"
    gw = sys_.overlay.gateways["solo"]
    assert gw.spills == 1 and gw.spill_failures == 1
    fb = next(j for j in cluster.jobs.values()
              if j.spec.fields["u"] == "fb")
    assert fb.state.value == "Completed"


# ---------------------------------------------------------------------------
# the equivalence property: legacy Nack path == new path with
# preemption/spill disabled (same admissions, same virtual timings)
# ---------------------------------------------------------------------------

def _drive_workload(sys_, jobs):
    """Submit jobs at their arrival times through one consumer; return
    {uid: (kind, detail)} outcomes + per-uid (start, finish) timings."""
    outcomes = {}
    for t, fields, uid in jobs:
        def submit(fields=fields, uid=uid):
            sys_.client.consumer.express(
                Interest(name=canonical_job_name(fields),
                         lifetime=2.0, must_be_fresh=True),
                on_data=lambda d, uid=uid: outcomes.setdefault(
                    uid, ("receipt", d.json()["state"])),
                on_fail=lambda r, uid=uid: outcomes.setdefault(
                    uid, ("fail", r)),
                retries=0)
        sys_.net.schedule(t, submit)
    sys_.net.run()
    timings = {}
    for cluster in sys_.overlay.clusters.values():
        for job in cluster.jobs.values():
            timings[job.spec.fields["u"]] = (
                job.started_at, job.finished_at, job.state.value)
    return outcomes, timings


def _random_workload(seed, n=30):
    rng = random.Random(seed)
    jobs = []
    t = 0.3
    for i in range(n):
        t += rng.random() * 0.8
        fields = {"app": "sim", "chips": rng.choice([1, 2, 4]),
                  "d": round(rng.uniform(0.2, 3.0), 3), "u": f"j{seed}-{i}"}
        jobs.append((round(t, 3), fields, fields["u"]))
    return jobs


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_legacy_nack_path_equivalent_to_new_scheduler(seed):
    cfg = SchedulerConfig(preemption=False)     # spill off by default too
    jobs = _random_workload(seed)
    new_sys, _ = build_system(chips=4, max_queue_depth=2, config=cfg,
                              legacy_nack=False)
    old_sys, _ = build_system(chips=4, max_queue_depth=2, config=cfg,
                              legacy_nack=True)
    new_out, new_t = _drive_workload(new_sys, list(jobs))
    old_out, old_t = _drive_workload(old_sys, list(jobs))
    # identical admissions with identical virtual start/finish times
    assert new_t == old_t
    assert set(new_out) == set(old_out)
    for uid in new_out:
        nk, nd = new_out[uid]
        ok, od = old_out[uid]
        assert nk == ok
        if nk == "fail":
            # the only divergence allowed: what a rejected client learns
            assert reasons.is_busy_failure(nd)
            assert od.startswith(f"nack:{reasons.NO_CAPACITY}")
        else:
            assert nd == od


# ---------------------------------------------------------------------------
# review regressions
# ---------------------------------------------------------------------------

def test_failure_kind_classifies_on_first_wrapped_reason():
    # a busy receipt whose spill-failure detail embeds a no-route must
    # classify as busy (backoff), never as a transient no-route (free
    # immediate re-expressions at the saturated gateway)
    nested = "nack:busy:spill-failed:nack:no-route"
    assert reasons.is_busy_failure(nested)
    assert not reasons.is_no_route_failure(nested)
    assert reasons.is_no_route_failure("nack:no-route")
    assert not reasons.is_busy_failure("nack:no-route")
    assert reasons.failure_kind("timeout") == reasons.TIMEOUT


def test_preempt_mark_withdrawn_when_head_starts_on_freed_chips():
    """A victim marked for preemption must NOT release at its boundary if
    the blocked head already started off naturally freed chips."""
    net, log = Network(), []
    cluster = make_cluster(net, log, chips=8)
    victim = cluster.submit(spec("victim", chips=4, d=4.0, phases=4), now=0.0)
    cluster.submit(spec("filler", chips=4, d=0.6), now=0.0)
    urgent = {"job": None}

    def submit_urgent():
        urgent["job"] = cluster.submit(
            spec("urgent", chips=4, d=0.5, prio=5), now=net.now)

    net.schedule(0.3, submit_urgent)
    net.run()
    # the filler's chips (freed at 0.6) started the urgent job; the
    # victim's mark was reconciled away and it ran to completion whole
    assert urgent["job"].started_at == pytest.approx(0.6)
    assert victim.preemptions == 0
    assert victim.finished_at == pytest.approx(4.0)
    assert cluster.scheduler.stats["preemptions"] == 0
    assert cluster.scheduler.stats["resumes"] == 0


def test_spill_fallback_failed_job_not_reinserted_into_dedupe_map():
    """A spill fallback whose local admission fails synchronously must
    not park the dead signature in the gateway dedupe map forever."""
    sys_ = LidcSystem()

    def boom(job, cl):
        raise RuntimeError("synthetic")

    cluster = ComputeCluster(sys_.net, "solo", chips=4, lake=sys_.lake,
                             max_queue_depth=8,
                             scheduler_config=spill_config())
    cluster.add_endpoint(ServiceEndpoint(service="sim.svc", app="sim",
                                         executor=boom))
    sys_.overlay.add_cluster(cluster, validators=sim_validators())
    sys_.net.run(until=0.2)
    client = LidcClient(sys_.net, cluster.node, name="local-client")
    out = {}
    # saturate the cluster so the job spills; free the chips again before
    # the (peer-less) spill gives up, so the fallback admission *starts*
    # the job, whose executor fails synchronously
    sys_.net.schedule(0.25 - sys_.net.now,
                      lambda: setattr(cluster, "free_chips", 0))
    express_at(sys_, client.consumer, 0.3,
               {"app": "sim", "chips": 4, "d": 1, "u": "sf"}, out, "sf",
               retries=1)
    sys_.net.schedule(1.0 - sys_.net.now,
                      lambda: setattr(cluster, "free_chips", 4))
    sys_.net.run()
    gw = sys_.overlay.gateways["solo"]
    assert gw.spills == 1 and gw.spill_failures == 1
    failed = [j for j in cluster.jobs.values()
              if j.spec.fields.get("u") == "sf"]
    assert failed and failed[0].state.value == "Failed"
    assert gw._jobs_by_sig == {}        # terminal job never (re-)entered
