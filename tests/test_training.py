"""Trainer, optimizer, checkpointing, data pipeline, serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (latest_step, restore_checkpoint,
                                   save_checkpoint)
from repro.configs.base import smoke_of, get_config
from repro.data.pipeline import SyntheticLM
from repro.datalake import DataLake, DirStore
from repro.models import bundle_for
from repro.optim import AdamW, constant, warmup_cosine
from repro.optim.compress import compress_grads_with_feedback
from repro.train.step import make_train_state, make_train_step
from repro.train.trainer import run_training

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
def test_training_loss_decreases():
    cfg = get_config("lidc-demo")
    res = run_training(cfg, steps=30, batch=8, seq=32, lr=3e-3, seed=1)
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.2, (first, last)


def test_adamw_step_math():
    opt = AdamW(lr=constant(0.1), b1=0.9, b2=0.99, weight_decay=0.0,
                grad_clip=0.0)
    params = {"w": jnp.ones((3, 3))}
    state = opt.init(params)
    grads = {"w": jnp.full((3, 3), 0.5)}
    new_params, state, metrics = opt.update(grads, state, params)
    # first step: mhat = g, vhat = g^2 -> delta = g/(|g|+eps) = sign(g)
    np.testing.assert_allclose(np.asarray(new_params["w"]),
                               np.ones((3, 3)) - 0.1, atol=1e-5)
    assert float(metrics["grad_norm"]) == pytest.approx(0.5 * 3, abs=1e-5)


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=0.01)


@pytest.mark.slow
def test_microbatch_grad_accumulation_equivalent():
    cfg = smoke_of("qwen2-0.5b")
    opt = AdamW(lr=constant(1e-3))
    state = make_train_state(cfg, KEY, opt)
    pipe = SyntheticLM(cfg, batch=8, seq=16, seed=0)
    batch = jax.tree.map(jnp.asarray, next(iter(pipe)))
    s1 = make_train_step(cfg, opt)
    s2 = make_train_step(cfg, opt, microbatch=4)
    _, m1 = s1(state, batch)
    _, m2 = s2(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]),
                                                   rel=1e-3)


def test_checkpoint_roundtrip_exact():
    lake = DataLake()
    cfg = smoke_of("qwen3-1.7b")
    opt = AdamW(lr=constant(1e-3))
    state = make_train_state(cfg, KEY, opt)
    save_checkpoint(lake, "runA", 7, state)
    assert latest_step(lake, "runA") == 7
    template = jax.eval_shape(lambda: state)
    restored, step = restore_checkpoint(lake, "runA", template)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@pytest.mark.slow
def test_checkpoint_resume_continues_run():
    lake = DataLake()
    cfg = get_config("lidc-demo")
    run_training(cfg, steps=6, batch=4, seq=16, lake=lake,
                      run_name="resume-test", ckpt_every=3)
    assert latest_step(lake, "resume-test") == 6
    r2 = run_training(cfg, steps=10, batch=4, seq=16, lake=lake,
                      run_name="resume-test", ckpt_every=3)
    assert r2.resumed_from == 6
    assert r2.steps_done == 10
    assert len(r2.losses) == 4          # only the new steps ran


def test_dirstore_survives_reopen(tmp_path):
    lake1 = DataLake(store=DirStore(str(tmp_path)))
    from repro.core.names import Name
    name = Name.parse("/lidc/data/blob")
    lake1.put_bytes(name, b"x" * (3 * 2 ** 20))   # segmented (3 MiB)
    lake2 = DataLake(store=DirStore(str(tmp_path)))
    assert lake2.get_bytes(name) == b"x" * (3 * 2 ** 20)


def test_lake_segmentation_roundtrip():
    lake = DataLake()
    from repro.core.names import Name
    blob = bytes(range(256)) * 8192 * 2           # 4 MiB
    name = Name.parse("/lidc/data/big")
    lake.put_bytes(name, blob)
    assert lake.get_bytes(name) == blob
    assert lake.has(name)


def test_grad_compression_error_feedback():
    """Quantize-with-feedback: errors cancel over steps (mean error -> 0)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(256,)),
                          jnp.float32)}
    err = None
    total_deq = jnp.zeros((256,))
    for _ in range(50):
        deq, err = compress_grads_with_feedback(g, err)
        total_deq = total_deq + deq["w"]
    avg = total_deq / 50
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g["w"]),
                               atol=2e-2)


def test_synthetic_data_is_learnable_and_deterministic():
    cfg = get_config("lidc-demo")
    a = next(iter(SyntheticLM(cfg, 4, 32, seed=5)))
    b = next(iter(SyntheticLM(cfg, 4, 32, seed=5)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_serve_engine_continuous_batching():
    from repro.serve.engine import ServeEngine
    cfg = get_config("lidc-demo")
    bundle = bundle_for(cfg)
    params = bundle.init(cfg, KEY)
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab, 6)), max_new=5)
            for _ in range(5)]
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) >= 5 for r in done)
    assert eng.tokens_out > 0


def test_serve_engine_matches_single_request():
    """Batched continuous decoding == one-at-a-time decoding (greedy)."""
    from repro.serve.engine import ServeEngine
    cfg = get_config("lidc-demo")
    bundle = bundle_for(cfg)
    params = bundle.init(cfg, KEY)
    prompts = [[1, 2, 3, 4], [7, 8, 9, 10, 11], [42, 5]]

    solo_outs = []
    for p in prompts:
        eng = ServeEngine(cfg, params, max_batch=1, max_seq=32)
        r = eng.submit(p, max_new=6)
        eng.run()
        solo_outs.append(r.out)

    eng = ServeEngine(cfg, params, max_batch=3, max_seq=32)
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run()
    for r, want in zip(reqs, solo_outs):
        assert r.out == want
