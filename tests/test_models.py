"""Per-arch smoke tests + mathematical consistency of the model families.

Every assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU with shape + finiteness asserts; family math is
cross-checked (chunked SSD vs sequential scan, mLSTM parallel vs recurrent,
decode vs teacher-forced full forward).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeConfig, registry, smoke_of
from repro.models import bundle_for, param_count, synth_batch
from repro.models.model import model_flops

KEY = jax.random.PRNGKey(0)
TRAIN = ShapeConfig("t", "train", 32, 2)

ALL_ARCHS = [a for a in registry() if a != "lidc-demo"] + ["lidc-demo"]

# archs whose reduced-config train step still takes ~20s of XLA compile on
# CPU; slow-marked so the default loop keeps the cheap arch smokes only
_SLOW_TRAIN_ARCHS = {"qwen3-moe-30b-a3b", "zamba2-2.7b", "xlstm-350m"}
TRAIN_ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_TRAIN_ARCHS else a
    for a in ALL_ARCHS
]


@pytest.mark.parametrize("arch", TRAIN_ARCH_PARAMS)
def test_smoke_train_step(arch):
    """One real forward + grad step on the reduced config."""
    cfg = smoke_of(arch)
    bundle = bundle_for(cfg)
    params = bundle.init(cfg, KEY)
    batch = synth_batch(cfg, TRAIN, KEY)
    loss, grads = jax.value_and_grad(
        lambda p: bundle.loss_fn(cfg, p, batch))(params)
    assert jnp.isfinite(loss), arch
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0, arch
    # a gradient step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype),
                           params, grads)
    loss2 = bundle.loss_fn(cfg, params2, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = smoke_of(arch)
    bundle = bundle_for(cfg)
    params = bundle.init(cfg, KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (B, S, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        logits, cache = bundle.prefill(cfg, params,
                                       {"frames": frames, "tokens": toks},
                                       max_seq=S + 4)
    else:
        logits, cache = bundle.prefill(cfg, params, toks, max_seq=S + 4)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    l2, cache2 = bundle.decode_step(cfg, params, cache, nxt)
    assert l2.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(l2.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_and_flops_positive(arch):
    cfg = smoke_of(arch)
    n = param_count(cfg)
    assert n > 0
    assert param_count(cfg, active_only=True) <= n
    for shape in SHAPES.values():
        assert model_flops(cfg, shape) > 0


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "qwen2-0.5b",
                                  "qwen3-moe-30b-a3b"])
def test_decode_matches_teacher_forcing(arch):
    """Greedy decode logits == full-forward logits at the same positions."""
    import dataclasses
    cfg = smoke_of(arch)
    if cfg.is_moe:
        # decode routes one token at a time; with production capacity the
        # full-forward path may drop tokens the decode path keeps — give
        # the consistency check drop-free capacity
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    bundle = bundle_for(cfg)
    params = bundle.init(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab)
    full = bundle.apply(cfg, params, toks)
    _, cache = bundle.prefill(cfg, params, toks[:, :6], max_seq=12)
    outs = []
    for i in range(6, 12):
        lg, cache = bundle.decode_step(cfg, params, cache, toks[:, i:i + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full[:, 6:12], np.float32),
                               atol=2e-2, rtol=2e-2)


def test_ssd_chunked_matches_sequential():
    """Mamba2 chunked SSD == naive per-step recurrence."""
    from repro.configs.base import smoke_of
    from repro.models import mamba2 as M
    cfg = smoke_of("zamba2-2.7b")
    d_inner, H, P, N = M.dims(cfg)
    B, S = 2, 32
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, H))
    Bm = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    d_skip = jnp.zeros((H,))
    y_chunk = M.ssd_forward(cfg, x, dt, a_log, Bm, Cm, d_skip)

    # sequential reference
    A = -jnp.exp(a_log)
    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        a_t = jnp.exp(dt[:, t] * A)                      # (B,H)
        upd = (dt[:, t, :, None] * x[:, t])[..., None] * Bm[:, t, None, None, :]
        state = a_t[..., None, None] * state + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", state, Cm[:, t]))
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=2e-4, rtol=2e-3)


def test_mlstm_parallel_matches_recurrent():
    """mLSTM stabilized parallel form == step-by-step recurrent cell."""
    from repro.configs.base import smoke_of
    from repro.models import xlstm as X
    cfg = smoke_of("xlstm-350m")
    bundle_params = X.init_mlstm_block(cfg, KEY, jnp.float32)
    p = bundle_params["mlstm"]
    d_inner, H, hd = X.dims(cfg)
    B, S = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(7), (B, S, cfg.d_model),
                          jnp.float32) * 0.3
    y_par = X.mlstm_parallel(cfg, p, x)

    cell = {"C": jnp.zeros((B, H, hd, hd)), "n": jnp.zeros((B, H, hd)),
            "m": jnp.full((B, H), -1e30),
            "conv": jnp.zeros((B, cfg.conv_kernel - 1, d_inner))}
    outs = []
    for t in range(S):
        o, cell = X.mlstm_step(cfg, p, x[:, t:t + 1], cell)
        outs.append(o)
    y_rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               atol=3e-4, rtol=3e-3)


@pytest.mark.slow
def test_hybrid_decode_matches_prefill_continuation():
    """zamba2: prefill(S) then decode == prefill(S+1) last logits."""
    cfg = smoke_of("zamba2-2.7b")
    bundle = bundle_for(cfg)
    params = bundle.init(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 17), 0, cfg.vocab)
    lg_full, _ = bundle.prefill(cfg, params, toks, max_seq=32)
    _, cache = bundle.prefill(cfg, params, toks[:, :16], max_seq=32)
    lg_dec, _ = bundle.decode_step(cfg, params, cache, toks[:, 16:17])
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0], np.float32),
                               np.asarray(lg_full[:, -1], np.float32),
                               atol=5e-2, rtol=5e-2)


def test_moe_local_dispatch_matches_dense():
    """Sort-based capacity dispatch == dense per-expert loop (no drops)."""
    from repro.models import moe as MoE
    cfg = smoke_of("qwen3-moe-30b-a3b")
    cfg = type(cfg)(**{**cfg.__dict__, "capacity_factor": 8.0})  # no drops
    p = MoE.init_moe(cfg, KEY, jnp.float32)
    T, D = 64, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(3), (T, D), jnp.float32)
    y, (f_e, p_e) = MoE._local_moe(cfg, x, p, 0, cfg.n_experts)
    assert float(jnp.sum(f_e)) > 0      # load-balance stats present

    # dense reference: every expert on every token, masked combine
    from repro.kernels import ref as kref
    logits = x @ p["router"]
    w, ids = kref.moe_gating_ref(logits, cfg.top_k)
    y_ref = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        o = h @ p["w_down"][e]
        mask = (ids == e).astype(jnp.float32) * w            # (T,k)
        y_ref = y_ref + o * jnp.sum(mask, axis=-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)


def test_moe_capacity_drops_tokens():
    from repro.models import moe as MoE
    cfg = smoke_of("qwen3-moe-30b-a3b")
    cfg = type(cfg)(**{**cfg.__dict__, "capacity_factor": 0.05})
    p = MoE.init_moe(cfg, KEY, jnp.float32)
    x = jax.random.normal(KEY, (64, cfg.d_model), jnp.float32)
    y, _ = MoE._local_moe(cfg, x, p, 0, cfg.n_experts)
    assert bool(jnp.all(jnp.isfinite(y)))   # drops must not produce NaNs
