"""The decentralized control plane vs the retained global-BFS oracle.

Covers the PR's acceptance properties:

* converged decentralized FIBs reproduce the oracle's reachability and
  shortest-path costs on random ring/tree/random topologies;
* withdrawal / leave / failure leave **no stale nexthops**;
* Fib and LinearFib removal/derivation stay symmetric (``sync_prefix``);
* advertisements are signed (a tampered or wrong-key advert is dropped);
* capability advertisements steer placement: a cluster that lowers its
  advertised chips mid-run stops receiving new compute Interests within
  one advertisement lifetime.
"""

import random

import pytest

from repro.core.forwarder import Network
from repro.core.names import Name
from repro.core.overlay import LidcSystem, MeshTopology
from repro.core.packets import Data
from repro.core.routing import RoutingConfig, capability_cost
from repro.core.strategy import AdaptiveStrategy
from repro.core.tables import Fib, LinearFib

# ---------------------------------------------------------------------------
# protocol == oracle (property tests)
# ---------------------------------------------------------------------------


def _serve(mesh, origin, prefix, tag=b"v"):
    def handler(interest, publish, now):
        return Data(name=interest.name, content=tag, created_at=now,
                    freshness=30.0)
    mesh.attach_producer(origin, Name.parse(prefix), handler)


def _build_random_scenario(seed: int):
    rng = random.Random(seed)
    kind = rng.choice(MeshTopology.KINDS)
    n = rng.randint(4, 10)
    mesh = MeshTopology(Network(), n, kind, seed=seed)
    announcements = []
    for p in range(rng.randint(1, 4)):
        prefix = f"/svc/p{p}"
        for origin in rng.sample(range(n), rng.randint(1, 2)):
            _serve(mesh, origin, prefix)
            announcements.append((origin, prefix))
    return rng, mesh, announcements


def _assert_matches_oracle(mesh):
    """Every alive node's FIB min cost == oracle min distance; withdrawn/
    unreachable prefixes have no live routes (is_converged checks both —
    here we assert it *stays* true, not just that converge() returned)."""
    assert mesh.is_converged()


@pytest.mark.parametrize("seed", range(12))
def test_converged_fibs_match_bfs_oracle_randomized(seed):
    rng, mesh, announcements = _build_random_scenario(seed)
    mesh.converge(timeout=20.0)
    _assert_matches_oracle(mesh)

    # withdraw a random announcement: no stale nexthops may survive
    origin, prefix = rng.choice(announcements)
    mesh.withdraw(origin, Name.parse(prefix))
    mesh.converge(timeout=20.0)
    _assert_matches_oracle(mesh)

    # fail a random non-origin node (the hard case: no withdrawal is sent)
    candidates = [i for i in range(len(mesh)) if i not in mesh.down]
    victim = rng.choice(candidates)
    mesh.fail_node(victim)
    mesh.converge(timeout=20.0)
    _assert_matches_oracle(mesh)


def test_converged_fibs_match_bfs_oracle_property():
    pytest.importorskip("hypothesis", reason="property sweep needs hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000), st.integers(0, 2))
    def check(seed, churn_kind):
        rng, mesh, announcements = _build_random_scenario(seed)
        mesh.converge(timeout=20.0)
        assert mesh.is_converged()
        if churn_kind == 1 and announcements:
            origin, prefix = rng.choice(announcements)
            mesh.withdraw(origin, Name.parse(prefix))
        elif churn_kind == 2:
            mesh.leave(rng.randrange(len(mesh)))
        mesh.converge(timeout=20.0)
        assert mesh.is_converged()

    check()


def test_withdrawal_leaves_no_stale_nexthops_anywhere():
    mesh = MeshTopology(Network(), 10, "random", seed=11)
    for origin in (0, 4, 7):
        _serve(mesh, origin, "/svc/shared")
    mesh.converge()
    for origin in (0, 4, 7):
        mesh.withdraw(origin, Name.parse("/svc/shared"))
    mesh.converge()
    for node in mesh.nodes:
        assert not node.fib.nexthops(Name.parse("/svc/shared")), node.name


def test_leave_and_fail_leave_no_dangling_faces():
    """The regression the RIB/FIB split fixes: routes through a departed
    node used to linger in other nodes' FIBs pointing at dead faces."""
    mesh = MeshTopology(Network(), 8, "ring")
    _serve(mesh, 2, "/svc/a")
    _serve(mesh, 6, "/svc/b")
    mesh.converge()
    mesh.leave(2)       # graceful: in-band withdrawal
    mesh.fail_node(6)   # abrupt: carrier/hello detection only
    mesh.converge(timeout=20.0)
    for idx, node in enumerate(mesh.nodes):
        if idx in mesh.down:
            continue
        for prefix in list(node.fib.prefixes()):
            for h in node.fib.nexthops(prefix).values():
                assert not node.faces[h.face_id].down, (
                    f"{node.name} keeps a nexthop for {prefix} "
                    f"through a dead face")


# ---------------------------------------------------------------------------
# Fib / LinearFib symmetry (sync_prefix is the derivation entry point)
# ---------------------------------------------------------------------------

def test_sync_prefix_sets_costs_up_and_down():
    """register() keeps the min cost ever seen — correct for additive
    announcements, wrong for re-derivation: a route whose path lengthened
    after a failure must be able to *raise* its cost."""
    for cls in (Fib, LinearFib):
        fib = cls()
        fib.register(Name.parse("/a"), 1, cost=2.0)
        fib.register(Name.parse("/a"), 1, cost=5.0)     # min-sticky: stays 2
        assert fib.nexthops(Name.parse("/a"))[1].cost == 2.0
        fib.sync_prefix(Name.parse("/a"), {1: 5.0})     # set semantics
        assert fib.nexthops(Name.parse("/a"))[1].cost == 5.0
        fib.sync_prefix(Name.parse("/a"), {1: 1.0, 2: 3.0})
        assert {f: h.cost for f, h in fib.nexthops(Name.parse("/a")).items()} \
            == {1: 1.0, 2: 3.0}
        fib.sync_prefix(Name.parse("/a"), {})
        assert fib.lookup(Name.parse("/a/x")) == (None, [])


def test_sync_prefix_preserves_learned_stats():
    fib = Fib()
    fib.register(Name.parse("/a"), 1, cost=1.0)
    hop = fib.nexthops(Name.parse("/a"))[1]
    hop.record(ok=True, rtt=0.25)
    fib.sync_prefix(Name.parse("/a"), {1: 4.0, 2: 1.0})
    kept = fib.nexthops(Name.parse("/a"))[1]
    assert kept is hop and kept.rtt_ewma == pytest.approx(0.25)
    assert kept.cost == 4.0


def test_sync_prefix_keeps_trie_and_linear_equivalent():
    """Mirrored op streams including sync_prefix (the new derivation op)
    keep the trie FIB and the linear oracle byte-identical — the symmetric
    removal regression test."""
    comps = ["a", "b", "c", "lidc", "compute", "x"]
    for trial in range(80):
        rng = random.Random(trial)
        trie, oracle = Fib(), LinearFib()
        for _ in range(rng.randint(1, 50)):
            name = Name(tuple(rng.choice(comps)
                              for _ in range(rng.randint(1, 4))))
            roll = rng.random()
            if roll < 0.4:
                cost = rng.choice([1.0, 2.0, 3.0])
                face = rng.randint(1, 5)
                trie.register(name, face, cost)
                oracle.register(name, face, cost)
            elif roll < 0.7:
                desired = {rng.randint(1, 5): float(rng.randint(1, 6))
                           for _ in range(rng.randint(0, 3))}
                assert (trie.sync_prefix(name, desired)
                        == oracle.sync_prefix(name, desired))
            elif roll < 0.85:
                fid = rng.randint(1, 5) if rng.random() < 0.5 else None
                trie.unregister(name, fid)
                oracle.unregister(name, fid)
            else:
                face = rng.randint(1, 5)
                trie.remove_face(face)
                oracle.remove_face(face)
        assert len(trie) == len(oracle)
        assert sorted(map(str, trie.prefixes())) \
            == sorted(map(str, oracle.prefixes()))
        for _ in range(25):
            q = Name(tuple(rng.choice(comps)
                           for _ in range(rng.randint(1, 5))))
            m1, h1 = trie.lookup(q)
            m2, h2 = oracle.lookup(q)
            assert (m1 is None) == (m2 is None), str(q)
            if m1 is not None:
                assert m1.components == m2.components
                assert ([(h.face_id, h.cost) for h in h1]
                        == [(h.face_id, h.cost) for h in h2])


# ---------------------------------------------------------------------------
# protocol plumbing edge cases
# ---------------------------------------------------------------------------

def test_fail_face_feeds_triggered_updates():
    """Forwarder.fail_face reports the dead link to the routing agent:
    RIB routes through it are purged and updates propagate."""
    net = Network()
    mesh = MeshTopology(net, 3, "ring")
    _serve(mesh, 2, "/svc/f")
    mesh.converge()
    node0 = mesh.nodes[0]
    face02 = mesh.faces[(0, 2)]
    assert face02.face_id in node0.fib.nexthops(Name.parse("/svc/f"))
    node0.fail_face(face02)
    net.run(until=net.now + 1.0)
    hops = node0.fib.nexthops(Name.parse("/svc/f"))
    assert face02.face_id not in hops
    assert hops, "the long-way route via node 1 must survive"


def test_malformed_and_nonneighbor_control_ignored():
    from repro.core.packets import Interest
    net = Network()
    mesh = MeshTopology(net, 2, "ring")
    agent = mesh.agents[0]
    rib_before = len(agent.rib)
    # control from a face that is not a declared adjacency: dropped
    agent.handle_control(9999, Interest(name=Name.parse("/lidc/rt/x/1"),
                                        app_params={"t": "adv", "advs": []}))
    # adverts missing mandatory fields: ignored, no crash
    nb_face = next(iter(agent.neighbors))
    agent.handle_control(nb_face, Interest(
        name=Name.parse("/lidc/rt/mesh1/1"),
        app_params={"t": "adv", "n": "mesh1", "advs": [{"p": "/a"}, {}]}))
    assert len(agent.rib) == rib_before


def test_withdraw_tombstone_blocks_stale_resurrection():
    """A late advertisement at or below the withdrawn sequence number must
    not resurrect the prefix (sequence-gated tombstones)."""
    net = Network()
    mesh = MeshTopology(net, 2, "ring")
    _serve(mesh, 0, "/svc/t")
    mesh.converge()
    agent1 = mesh.agents[1]
    stale = dict(next(iter(agent1.rib.routes(Name.parse("/svc/t")).values()
                           )).__dict__)
    mesh.withdraw(0, Name.parse("/svc/t"))
    mesh.converge()
    assert len(agent1.rib.routes(Name.parse("/svc/t"))) == 0
    # replay the pre-withdrawal advert (same seq): tombstone rejects it
    from repro.core.packets import Interest
    replay = {"p": "/svc/t", "o": stale["origin"], "s": stale["seq"],
              "c": 0.0, "pa": [stale["origin"]], "lt": stale["lifetime"],
              "sig": stale["sig"]}
    nb_face = next(iter(agent1.neighbors))
    agent1.handle_control(nb_face, Interest(
        name=Name.parse("/lidc/rt/mesh0/99"),
        app_params={"t": "adv", "n": "mesh0", "advs": [replay]}))
    net.run(until=net.now + 1.0)
    assert len(agent1.rib.routes(Name.parse("/svc/t"))) == 0
    assert not mesh.nodes[1].fib.nexthops(Name.parse("/svc/t"))


# ---------------------------------------------------------------------------
# advertisement authenticity
# ---------------------------------------------------------------------------

def test_adverts_signed_wrong_key_dropped():
    net = Network()
    good = MeshTopology(net, 2, "ring",
                        routing=RoutingConfig(sign_key=b"key-A"))
    # replace node 1's agent key: it now rejects node 0's advertisements
    good.agents[1].cfg = RoutingConfig(sign_key=b"key-B")
    _serve(good, 0, "/svc/sec")
    net.run(until=1.0)
    assert len(good.agents[1].rib) == 0
    assert good.agents[1].stats["dropped_bad_sig"] > 0


def test_adverts_accepted_with_shared_key():
    net = Network()
    mesh = MeshTopology(net, 2, "ring",
                        routing=RoutingConfig(sign_key=b"key-A"))
    _serve(mesh, 0, "/svc/sec")
    net.run(until=1.0)
    assert len(mesh.agents[1].rib) == 1
    assert mesh.agents[1].stats["dropped_bad_sig"] == 0


# ---------------------------------------------------------------------------
# capability advertisements
# ---------------------------------------------------------------------------

def test_capability_cost_orders_loaded_clusters_last():
    fresh = capability_cost({"chips": 8, "free_chips": 8, "queue_depth": 0})
    busy = capability_cost({"chips": 8, "free_chips": 0, "queue_depth": 2})
    drained = capability_cost({"chips": 0, "free_chips": 0})
    assert fresh < busy < drained


def test_cold_probe_seeded_by_advertised_capability_cost():
    """Line 0 — 1 — 2: both ends announce /svc/x, node 2 advertises no
    free capacity.  The very first (cold) Interest from node 1 must go to
    node 0 — the strategy's cold ranking is seeded from advertised cost
    before any RTT measurement exists."""
    net = Network()
    mesh = MeshTopology(net, 3, "tree",    # 1-0, 2-0 ... use explicit line
                        strategy_factory=lambda i: AdaptiveStrategy(
                            probe_fanout=1))
    calls = {"fresh": 0, "busy": 0}

    def make(tag):
        def handler(interest, publish, now):
            calls[tag] += 1
            return Data(name=interest.name, content=tag.encode(),
                        created_at=now, freshness=30.0)
        return handler

    mesh.nodes[1].attach_producer(Name.parse("/svc/x"), make("fresh"))
    mesh.announce(1, Name.parse("/svc/x"),
                  caps={"chips": 8, "free_chips": 8, "queue_depth": 0})
    mesh.nodes[2].attach_producer(Name.parse("/svc/x"), make("busy"))
    mesh.announce(2, Name.parse("/svc/x"),
                  caps={"chips": 8, "free_chips": 0, "queue_depth": 3})
    net.run(until=1.0)
    box = mesh.consumer_at(0).get(Name.parse("/svc/x/q"))
    assert box["data"].content == b"fresh"
    assert calls == {"fresh": 1, "busy": 0}


def test_lowered_chip_advertisement_stops_new_compute_interests():
    """ISSUE satellite: a cluster that lowers its advertised chips mid-run
    stops receiving new compute Interests within one advertisement
    lifetime (everything on the virtual clock, via matchmaker/gateway)."""
    from repro.runtime.fleet import standard_endpoints
    from repro.runtime.executors import memory_model

    cfg = RoutingConfig()       # stock timers; the bound is one lifetime
    sys_ = LidcSystem(routing=cfg)
    for name in ("podA", "podB"):
        sys_.add_cluster(name, chips=8,
                         endpoints=standard_endpoints(["lidc-demo"]),
                         memory_model=memory_model)

    def blast(tag):
        return {"app": "blast", "srr": "SRR2931415", "db": "human",
                "mem": 4, "cpu": 2, "tag": tag}

    # blast jobs span ~8 virtual hours; poll coarsely to keep the event
    # count (and wall time) down — the protocol rides the same clock
    h0 = sys_.client.run_job(blast("warmup"), interval=120.0)
    assert h0 is not None and h0.state == "Completed"
    victim = h0.result["cluster"]
    other = "podB" if victim == "podA" else "podA"
    gw_victim = sys_.overlay.gateways[victim]
    served_before = gw_victim.receipts_served

    # the victim drains itself: advertised chips drop to zero mid-run —
    # its compute prefixes are withdrawn in-band
    sys_.overlay.clusters[victim].advertise(chips=0)
    sys_.net.run(until=sys_.net.now + cfg.adv_lifetime)

    for i in range(2):
        h = sys_.client.run_job(blast(f"after-{i}"), interval=120.0)
        assert h is not None and h.state == "Completed"
        assert h.result["cluster"] == other
    assert gw_victim.receipts_served == served_before

    # restoring the advertisement brings the cluster back into rotation
    sys_.overlay.clusters[victim].advertise(chips=8)
    sys_.net.run(until=sys_.net.now + cfg.adv_lifetime)
    edge_hops = sys_.overlay.edge.fib.nexthops(
        Name.parse("/lidc/compute/blast"))
    assert len(edge_hops) == 2


def test_same_name_rejoin_outruns_withdrawal_tombstones():
    """A cluster that left (flooding withdrawals) can rejoin under the
    same name: the new agent's clock-seeded sequence numbers exceed the
    tombstoned withdrawal seqs, so its advertisements are not dropped."""
    from repro.runtime.fleet import standard_endpoints
    from repro.runtime.executors import memory_model

    sys_ = LidcSystem()
    for name in ("podA", "podB"):
        sys_.add_cluster(name, chips=8,
                         endpoints=standard_endpoints(["lidc-demo"]),
                         memory_model=memory_model)
    sys_.net.run(until=1.0)
    # leave and rejoin at the SAME virtual instant (reconfiguration
    # scripts do exactly this), well within the tombstones' lifetime
    sys_.overlay.remove_cluster("podA")
    sys_.add_cluster("podA", chips=8,
                     endpoints=standard_endpoints(["lidc-demo"]),
                     memory_model=memory_model)
    sys_.net.run(until=3.0)
    assert len(sys_.overlay.edge.fib.nexthops(
        Name.parse("/lidc/compute/blast"))) == 2


def test_refresh_gossips_live_load_signals():
    """Capability records are re-sampled at every refresh: a cluster whose
    chips fill up after origination gossips the *current* free_chips, not
    the snapshot taken when it joined."""
    from repro.runtime.fleet import standard_endpoints
    from repro.runtime.executors import memory_model

    cfg = RoutingConfig(refresh_interval=1.0)
    sys_ = LidcSystem(routing=cfg)
    sys_.add_cluster("pod", chips=8,
                     endpoints=standard_endpoints(["lidc-demo"]),
                     memory_model=memory_model)
    sys_.net.run(until=0.5)
    prefix = Name.parse("/lidc/compute/blast")
    assert sys_.overlay.edge_agent.advertised_capabilities(
        prefix)["pod"]["free_chips"] == 8
    sys_.overlay.clusters["pod"].free_chips = 0     # chips fill up mid-run
    sys_.net.run(until=sys_.net.now + 3 * cfg.refresh_interval)
    assert sys_.overlay.edge_agent.advertised_capabilities(
        prefix)["pod"]["free_chips"] == 0


def test_zero_preconfiguration_join():
    """Nothing ever writes the edge FIB: a fresh system's edge knows no
    routes until the gossip arrives, then jobs route normally."""
    from repro.runtime.fleet import standard_endpoints
    from repro.runtime.executors import memory_model

    sys_ = LidcSystem()
    sys_.add_cluster("solo", chips=8,
                     endpoints=standard_endpoints(["lidc-demo"]),
                     memory_model=memory_model)
    assert len(sys_.overlay.edge.fib) == 0          # zero pre-configuration
    sys_.net.run(until=0.1)
    assert len(sys_.overlay.edge.fib) > 0           # learned in-band
    caps = sys_.overlay.edge_agent.advertised_capabilities(
        Name.parse("/lidc/compute/blast"))
    assert caps["solo"]["chips"] == 8               # capability record rode along
    h = sys_.client.run_job({"app": "blast", "srr": "SRR2931415",
                             "db": "human", "mem": 4, "cpu": 2})
    assert h is not None and h.state == "Completed"
