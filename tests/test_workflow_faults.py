"""Deterministic fault injection: workflows complete exactly once under
link loss, cluster crash mid-stage, and overlay partition + heal.

The determinism contract (ISSUE acceptance): with a fixed seed, two runs
of the same faulty scenario produce byte-identical virtual-clock event
traces (engine trace + injector trace + executor log), and a mid-stage
cluster crash re-executes exactly one stage while the workflow still
completes.

Timing used below: the 6 MiB dataset shards in ~0.1 virtual seconds and
each 1 MiB align segment takes 0.5 s (apps.ALIGN_THROUGHPUT), so aligns
are in flight from ~0.3 s to ~0.8 s — faults injected at 0.45 s land
mid-align by construction.
"""

from repro.core.names import Name
from repro.core.strategy import AdaptiveStrategy
from repro.workflow import FaultInjector, WorkflowEngine, WorkflowSpec
from repro.workflow.apps import build_workflow_fleet

DATASET = "/lidc/data/reads/big"
N_CLUSTERS = 6
PARTS = 6          # one align per cluster under cold-probe rotation
DATA_BYTES = 6 * 2 ** 20
MID_ALIGN_T = 0.45


def build(tag="t"):
    system, log = build_workflow_fleet(
        N_CLUSTERS, chips=4,
        strategy=AdaptiveStrategy(probe_fanout=1, rotate_cold_probes=True))
    system.lake.put_bytes(Name.parse(DATASET),
                          bytes(range(256)) * (DATA_BYTES // 256))
    wf = (WorkflowSpec(f"blast-{tag}")
          .stage("shard", "wf-shard", inputs=[DATASET], parts=PARTS, tag=tag)
          .stage("align", "wf-align", inputs=["@shard"], fanout=PARTS,
                 tag=tag)
          .stage("merge", "wf-merge", inputs=["@align"], tag=tag)
          .compile())
    eng = WorkflowEngine(system.net, system.overlay.edge)
    inj = FaultInjector(system.net, seed=7)
    return system, log, wf, eng, inj


def first_align_cluster(log):
    """The (deterministic) cluster the first align instance landed on."""
    return next(c for _, app, c, _ in log.events if app == "wf-align")


# ---------------------------------------------------------------------------
# cluster crash mid-stage
# ---------------------------------------------------------------------------

def crash_scenario():
    system, log, wf, eng, inj = build()
    run = eng.start(wf)

    def crash():
        victim = first_align_cluster(log)
        system.overlay.fail_cluster(victim)
        inj.trace.append((round(system.net.now, 9), "crash-cluster", victim))

    system.net.schedule(MID_ALIGN_T, crash)
    system.net.run()
    return run, log, inj


def test_crash_mid_stage_reexecutes_exactly_one_stage():
    run, log, inj = crash_scenario()
    assert run.complete, run.stage_report()
    # the victim was mid-align: exactly that one stage ran twice
    reexec = log.reexecuted()
    assert len(reexec) == 1, (reexec, log.events)
    assert list(reexec.values()) == [2]
    # every other stage executed exactly once
    assert sorted(log.per_signature().values()) == [1] * 7 + [2]
    # the re-execution happened on a surviving cluster
    victim = inj.trace[0][2]
    resig = next(iter(reexec))
    runs_of_sig = [(t, c) for t, _, c, s in log.events if s == resig]
    assert runs_of_sig[0][1] == victim
    assert runs_of_sig[1][1] != victim
    # recovery latency: re-submission resolved within the poll/RTO budget
    crash_t = inj.trace[0][0]
    assert run.finished_at - crash_t < 10.0


def test_crash_trace_is_deterministic_across_runs():
    """Fixed seed => identical virtual-clock event traces, twice."""
    run_a, log_a, inj_a = crash_scenario()
    run_b, log_b, inj_b = crash_scenario()
    assert run_a.trace == run_b.trace
    assert inj_a.trace == inj_b.trace
    assert log_a.events == log_b.events
    assert run_a.makespan == run_b.makespan


# ---------------------------------------------------------------------------
# overlay partition + heal
# ---------------------------------------------------------------------------

def test_partition_heals_without_reexecution():
    """A partitioned cluster stays alive: its in-flight stage still lands
    in the (service-separate) data lake, so the engine's retry is served
    from the result cache — completion with zero re-executions."""
    system, log, wf, eng, inj = build(tag="part")
    run = eng.start(wf)

    def cut():
        victim = first_align_cluster(log)
        system.overlay.partition([victim])
        inj.trace.append((round(system.net.now, 9), "partition", victim))
        inj.heal_partition(system.overlay, [victim], at=system.net.now + 8.0)

    system.net.schedule(MID_ALIGN_T, cut)
    system.net.run()
    assert run.complete, run.stage_report()
    assert log.reexecuted() == {}
    assert sorted(log.per_signature().values()) == [1] * 8
    assert run.resubmissions >= 1          # the engine did have to retry
    assert any(kind == "heal-partition" for _, kind, _ in inj.trace)


# ---------------------------------------------------------------------------
# lossy / slow links
# ---------------------------------------------------------------------------

def lossy_scenario(rate=0.25):
    system, log, wf, eng, inj = build(tag="lossy")
    # both directions of every edge<->cluster link drop packets
    faces = [f for pair in system.overlay.links.values() for f in pair]
    inj.lossy_link(faces, rate, start=0.0)
    run = eng.start(wf)
    system.net.run()
    return run, log, inj


def test_workflow_survives_lossy_links_deterministically():
    run_a, log_a, _ = lossy_scenario()
    assert run_a.complete, run_a.stage_report()
    # loss costs retransmissions/duplicate receipts, never duplicate *work*
    # beyond per-stage re-submission (counted), and the trace is replayable
    run_b, log_b, _ = lossy_scenario()
    assert run_a.trace == run_b.trace
    assert log_a.events == log_b.events


def test_delayed_links_slow_but_complete():
    system, log, wf, eng, inj = build(tag="slow")
    _, _, bwf, beng, _ = build(tag="slow")   # fresh twin: baseline makespan
    base = beng.run(bwf)
    faces = [f for pair in system.overlay.links.values() for f in pair]
    inj.delay_link(faces, 0.05, start=0.0)
    run = eng.run(wf)
    assert run.complete and base.complete
    assert run.makespan > base.makespan
