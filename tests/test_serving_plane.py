"""The named inference serving plane: naming, gateway, KV cache, failover."""

import pytest

from repro.core.cluster import ComputeCluster
from repro.core.compute_plane import SchedulerConfig
from repro.core.jobs import JobSpec
from repro.core.names import (SERVE_PREFIX, Name, serve_fields_of,
                              serve_session_name)
from repro.core.overlay import LidcSystem
from repro.core.strategy import AdaptiveStrategy
from repro.core.validation import ValidationError, default_registry
from repro.datalake import DataLake
from repro.datalake.kv import (block_digests, chunk_name, kv_block_name,
                               longest_cached_prefix, prompt_digest,
                               publish_prefix_blocks, publish_prompt,
                               session_ckpt_name)
from repro.serve.plane import (ServeModelSpec, ServingPlane, SessionClient,
                               token_at)

MODEL = "qwen3-1.7b"


def build(n=3, *, decode_step_s=0.02, spill_queue_depth=2, chips=4):
    sys_ = LidcSystem(strategy=AdaptiveStrategy(
        probe_fanout=1, rotate_cold_probes=True, cost_bias=1.0,
        eta_weight=1.0))
    planes = {}
    for i in range(n):
        cfg = SchedulerConfig(spill_queue_depth=spill_queue_depth)
        cl = ComputeCluster(sys_.net, f"pod{i}", chips=chips,
                            lake=sys_.lake, max_queue_depth=8,
                            scheduler_config=cfg)
        planes[cl.name] = ServingPlane(
            cl, ServeModelSpec(model=MODEL, decode_step_s=decode_step_s))
        sys_.overlay.add_cluster(cl, validators=default_registry(),
                                 latency=0.002)
    sys_.net.run(until=0.25)
    return sys_, planes


# ---------------------------------------------------------------- naming
def test_serve_session_name_roundtrip():
    fields = {"sid": "s-1", "p": "ab12cd34", "ptoks": 100, "max_new": 16,
              "family": "dense"}
    name = serve_session_name(MODEL, fields)
    assert str(name).startswith(f"{SERVE_PREFIX}/{MODEL}/")
    back = serve_fields_of(name)
    assert back is not None
    assert back["app"] == "serve"
    assert back["arch"] == MODEL
    assert back["sid"] == "s-1" and back["p"] == "ab12cd34"
    assert back["ptoks"] == "100" and back["max_new"] == "16"


def test_serve_fields_of_rejects_malformed():
    assert serve_fields_of(Name.parse("/lidc/compute/train/m")) is None
    assert serve_fields_of(Name.parse(SERVE_PREFIX)) is None
    # a malformed k=v tail must reject, not raise (the gateway answers a
    # Nack on None)
    bad = Name.parse(SERVE_PREFIX).append(MODEL, "sid=s-1&broken")
    assert serve_fields_of(bad) is None
    # extra positional components are not a session name
    assert serve_fields_of(
        Name.parse(SERVE_PREFIX).append(MODEL, "x", "sid=1")) is None


def test_canonical_ordering_dedupes_sessions():
    a = serve_session_name(MODEL, {"sid": "s", "p": "d", "ptoks": 2})
    b = serve_session_name(MODEL, {"ptoks": 2, "p": "d", "sid": "s"})
    assert a == b


# ------------------------------------------------------------- kv naming
def test_block_digests_chain_commits_to_left_context():
    toks = list(range(128))
    d = block_digests(MODEL, toks, 32)
    assert len(d) == 4
    # shared prefix -> shared leading digests, divergence kills the rest
    other = toks[:64] + [9999] + toks[65:]
    d2 = block_digests(MODEL, other, 32)
    assert d2[:2] == d[:2] and d2[2:] != d[2:]
    # a different model shares nothing
    assert block_digests("other-model", toks, 32)[0] != d[0]
    # partial trailing block gets no digest
    assert len(block_digests(MODEL, toks[:100], 32)) == 3


def test_longest_cached_prefix_walks_longest_first():
    lake = DataLake()
    toks = list(range(128))
    publish_prefix_blocks(lake, MODEL, toks[:64], block_tokens=32,
                          kv_bytes_per_token=10.0)
    cached_toks, blocks = longest_cached_prefix(lake, MODEL, toks,
                                               block_tokens=32)
    assert (cached_toks, blocks) == (64, 2)
    assert longest_cached_prefix(lake, MODEL, [5, 6, 7],
                                 block_tokens=32) == (0, 0)
    # republish dedupes: nothing new for an already-named prefix
    assert publish_prefix_blocks(lake, MODEL, toks[:64],
                                 block_tokens=32) == 0
    assert lake.has(kv_block_name(MODEL, block_digests(MODEL, toks, 32)[0]))


def test_prompt_publication_dedupes():
    lake = DataLake()
    d1 = publish_prompt(lake, [1, 2, 3])
    puts = lake.puts
    d2 = publish_prompt(lake, [1, 2, 3])
    assert d1 == d2 and lake.puts == puts


# ----------------------------------------------------- capability gossip
def test_cluster_advertises_serve_families_and_prefixes():
    sys_, planes = build(1)
    cl = next(iter(sys_.overlay.clusters.values()))
    caps = cl.capabilities()
    assert caps["serve_families"] == ("dense",)
    prefixes = {str(p) for p in cl.advertised_prefixes()}
    assert SERVE_PREFIX in prefixes
    assert f"{SERVE_PREFIX}/{MODEL}" in prefixes
    # draining withdraws the serve prefixes with the compute ones
    cl.advertise(chips=0)
    prefixes = {str(p) for p in cl.advertised_prefixes()}
    assert SERVE_PREFIX not in prefixes


def test_validate_serve_rejects_unsupported_family():
    reg = default_registry()
    caps = {"archs": (MODEL,), "shapes": (), "chips": 4,
            "serve_families": ("dense",)}
    reg.validate("serve", {"arch": MODEL, "family": "dense"}, caps)
    with pytest.raises(ValidationError, match="families"):
        reg.validate("serve", {"arch": MODEL, "family": "moe"}, caps)
    with pytest.raises(ValidationError, match="max_new"):
        reg.validate("serve", {"arch": MODEL, "max_new": -1}, caps)


# ------------------------------------------------------------- sessions
def test_session_streams_deterministic_tokens():
    sys_, planes = build(3)
    client = SessionClient(sys_.net, sys_.overlay.edge, sys_.lake)
    prompt = list(range(70))
    r = client.start("t-1", MODEL, prompt, max_new=20)
    sys_.net.run()
    assert r.finished and r.ttft is not None and r.ttft > 0
    pd = prompt_digest(prompt)
    assert r.stream() == [token_at(pd, i) for i in range(20)]
    # the chunk stream and the resume checkpoint are named in the lake
    assert sys_.lake.has(chunk_name("t-1", 0))
    assert sys_.lake.get_json(session_ckpt_name("t-1"))["tokens_done"] == 20


def test_session_eta_is_structural():
    sys_, planes = build(1)
    cl = next(iter(sys_.overlay.clusters.values()))
    spec = JobSpec(app="serve", fields={"arch": MODEL, "ptoks": 8000,
                                        "max_new": 100})
    # never-observed work, yet the estimate is exact: prefill + decode
    est = cl.scheduler.run_estimate(spec)
    assert est == pytest.approx(8000 / 8000.0 + 100 * 0.02)
    # non-serve work falls through to the learned model / prior
    assert cl.scheduler.run_estimate(
        JobSpec(app="train", fields={})) == cl.scheduler.cfg.default_run_estimate


def test_second_session_hits_named_prefix_cache():
    sys_, planes = build(3)
    client = SessionClient(sys_.net, sys_.overlay.edge, sys_.lake)
    system = list(range(96))
    client.start("p-1", MODEL, system + [1000, 1001], max_new=8)
    sys_.net.run()
    r2 = client.start("p-2", MODEL, system + [2000, 2001], max_new=8)
    sys_.net.run()
    assert r2.finished
    stats = {k: sum(p.stats[k] for p in planes.values())
             for k in ("prefix_hits", "prefix_blocks_hit")}
    assert stats["prefix_hits"] >= 1
    assert stats["prefix_blocks_hit"] >= 3        # 96 tokens / 32 per block


def test_max_new_zero_session_completes_via_receipt():
    sys_, planes = build(2)
    client = SessionClient(sys_.net, sys_.overlay.edge, sys_.lake)
    r = client.start("z-1", MODEL, list(range(10)), max_new=0)
    sys_.net.run()
    assert r.finished and r.stream() == [] and r.ttft is None


def test_unsupported_family_session_rejected_in_network():
    sys_, planes = build(2)
    client = SessionClient(sys_.net, sys_.overlay.edge, sys_.lake)
    r = client.start("bad-1", MODEL, list(range(10)), max_new=4,
                     family="moe")
    sys_.net.run()
    assert not r.finished
    assert r.failed is not None


def test_cluster_kill_resumes_from_named_kv_elsewhere():
    sys_, planes = build(3, decode_step_s=0.05)
    client = SessionClient(sys_.net, sys_.overlay.edge, sys_.lake,
                           stall_timeout=1.5)
    prompt = list(range(64))
    r = client.start("k-1", MODEL, prompt, max_new=80)   # 4 s decode
    killed = {}

    def kill():
        for name, p in planes.items():
            if p.stats["sessions"] > 0:
                killed["name"] = name
                sys_.overlay.fail_cluster(name)
                return
    sys_.net.schedule(1.5, kill)
    sys_.net.run(until=60.0)
    sys_.net.run()
    assert killed, "no cluster was serving the session"
    assert r.finished and r.resubmits >= 1
    pd = prompt_digest(prompt)
    assert r.stream() == [token_at(pd, i) for i in range(80)]
    survivor_stats = [p.stats for n, p in planes.items()
                      if n != killed["name"]]
    assert sum(s["resumes"] for s in survivor_stats) >= 1
    assert sum(s["kv_fetches"] for s in survivor_stats) >= 1
    # the resuming cluster skipped the already-streamed chunks: total
    # chunk publications stay close to the unbroken count (overlap of at
    # most the in-flight chunk, not a from-scratch replay)
    total_chunks = sum(p.stats["chunks"] for p in planes.values())
    unbroken = 1 + (80 - 1 + 7) // 8            # chunk0 + ceil(79/8)
    assert total_chunks <= unbroken + 2
