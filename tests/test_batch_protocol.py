"""Wire-level batch submission protocol: names, receipts, dedupe, status.

These tests speak the protocol directly — raw Interests through the
overlay edge — so they pin the gateway's batch contract independently of
the TaskMapExecutor client: receipt shape, deterministic batch-id
dedupe, malformed-name rejection, compressed done ranges, avoid=
steering, and the coalesced ``ids=`` multi-status answer.
"""

import hashlib

import pytest

from repro.core.forwarder import Consumer
from repro.core.gateway import MAX_BATCH_MEMBERS
from repro.core.jobs import (AVOID_FIELD, INPUTS_FIELD, compress_ranges,
                             encode_input_names, expand_ranges)
from repro.core.names import (BATCH_PREFIX, DATA_PREFIX, STATUS_PREFIX, Name,
                              batch_fields_of, batch_job_name)
from repro.core.packets import Interest
from repro.workflow.taskmap import build_taskmap_fleet

DATASET = Name.parse(DATA_PREFIX).append("text", "bp")
RECORD = b"one two three four five six seven eight nine ten "  # 50 B


def fleet(n=1, *, chips=8, records=32, **kw):
    system, log = build_taskmap_fleet(n, chips=chips, segment_size=200, **kw)
    system.lake.put_bytes(DATASET, RECORD * records)
    system.net.run(until=system.net.now + 5)
    return system, log


def template(cost="5.0", **extra):
    return {"app": "tm-map", "fn": "wordcount",
            INPUTS_FIELD: encode_input_names([DATASET]),
            "parts": 8, "segs": 8, "spt": 1, "cost": cost, **extra}


class Express:
    """Capture one Interest's outcome (data payload or failure reason)."""

    def __init__(self, system):
        self.consumer = Consumer(system.net, system.overlay.edge, name="bp")
        self.net = system.net

    def __call__(self, name, *, lifetime=4.0):
        box = {}
        self.consumer.express(
            Interest(name=name, lifetime=lifetime, must_be_fresh=True),
            on_data=lambda d: box.setdefault("data", d),
            on_fail=lambda r: box.setdefault("fail", r),
            retries=0)
        # advance virtual time only until the answer lands, so callers
        # can observe intermediate job states
        deadline = self.net.now + 3 * lifetime
        while not box and self.net.now < deadline:
            self.net.run(until=self.net.now + 0.05)
        return box


# ---------------------------------------------------------------------------
# name codec
# ---------------------------------------------------------------------------

def test_batch_name_codec_round_trips():
    fields = {"app": "tm-map", "fn": "wordcount", "parts": 100}
    name = batch_job_name(fields, 0, 50)
    assert str(name).startswith(BATCH_PREFIX + "/tm-map/")
    got = batch_fields_of(name)
    assert got is not None
    f, lo, hi = got
    assert (lo, hi) == (0, 50)
    assert f["app"] == "tm-map" and f["fn"] == "wordcount"
    assert f["parts"] == "100"
    assert "lo" not in f and "hi" not in f


def test_batch_name_rejects_range_and_reserved_fields():
    with pytest.raises(ValueError):
        batch_job_name({"app": "tm-map"}, 5, 5)          # empty range
    with pytest.raises(ValueError):
        batch_job_name({"app": "tm-map"}, -1, 5)         # negative lo
    with pytest.raises(ValueError):
        batch_job_name({"app": "tm-map", "lo": 1}, 0, 5)  # reserved field
    with pytest.raises(ValueError):
        batch_job_name({"app": "tm-map", "part": 1}, 0, 5)
    with pytest.raises(ValueError):
        batch_job_name({"fn": "wordcount"}, 0, 5)        # no app
    # non-batch names decode to None, not an exception
    assert batch_fields_of(Name.parse("/lidc/compute/tm-map/part=0")) is None
    assert batch_fields_of(Name.parse(BATCH_PREFIX + "/tm-map")) is None


def test_range_compression_round_trips():
    parts = {0, 1, 2, 5, 6, 9}
    ranges = compress_ranges(parts)
    assert ranges == [[0, 3], [5, 7], [9, 10]]
    assert set(expand_ranges(ranges)) == parts
    assert compress_ranges([]) == []
    assert list(expand_ranges([])) == []


# ---------------------------------------------------------------------------
# receipts + dedupe
# ---------------------------------------------------------------------------

def test_batch_receipt_shape_and_deterministic_id():
    system, _ = fleet()
    express = Express(system)
    name = batch_job_name(template(), 0, 8)
    box = express(name)
    assert "data" in box, box.get("fail")
    receipt = box["data"].json()
    expect_bid = hashlib.sha256(str(name).encode()).hexdigest()[:12]
    assert receipt["batch_id"] == expect_bid
    assert receipt["state"] == "Running"
    assert receipt["cluster"] == "tmpod0"
    assert (receipt["lo"], receipt["hi"]) == (0, 8)
    assert receipt["admitted"] == 8
    assert receipt["cached"] == []
    assert receipt["status_name"] == (
        f"{STATUS_PREFIX}/tmpod0/batch/{expect_bid}")


def test_batch_retransmit_dedupes_onto_existing_record():
    system, log = fleet()
    express = Express(system)
    name = batch_job_name(template(), 0, 8)
    first = express(name)["data"].json()
    jobs_after_first = len(system.overlay.clusters["tmpod0"].jobs)
    # a retransmitted batch Interest (client crash, timeout retry) lands
    # on the existing record: same batch id, ZERO new jobs
    system.net.run(until=system.net.now + 2.0)   # past receipt freshness
    second = express(name)["data"].json()
    assert second["batch_id"] == first["batch_id"]
    assert len(system.overlay.clusters["tmpod0"].jobs) == jobs_after_first
    system.net.run()
    assert log.reexecuted() == {}


def test_malformed_batch_names_rejected():
    system, _ = fleet()
    express = Express(system)
    base = Name.parse(BATCH_PREFIX).append("tm-map")
    # inverted range never validates client-side, so build it by hand
    box = express(base.append("cost=5.0&fn=wordcount&hi=0&lo=8"))
    assert "fail" in box
    # a range wider than the gateway cap is refused outright
    too_wide = batch_job_name(template(), 0, MAX_BATCH_MEMBERS + 1)
    box = express(too_wide)
    assert "fail" in box


def test_avoided_cluster_answers_busy():
    system, _ = fleet()
    express = Express(system)
    name = batch_job_name(template(**{AVOID_FIELD: "tmpod0"}), 0, 8)
    box = express(name)
    assert "fail" in box
    assert "busy" in box["fail"]
    gw = system.overlay.gateways["tmpod0"]
    assert gw.avoided == 1
    # nothing was admitted
    assert len(system.overlay.clusters["tmpod0"].jobs) == 0


# ---------------------------------------------------------------------------
# batch + multi-job status
# ---------------------------------------------------------------------------

def test_batch_status_lifecycle_done_ranges_grow():
    system, _ = fleet(chips=4)                   # 2 waves of 4
    express = Express(system)
    receipt = express(batch_job_name(template(cost="1.0"), 0, 8))[
        "data"].json()
    status_name = Name.parse(receipt["status_name"])
    st1 = express(status_name)["data"].json()
    assert st1["state"] == "Running"
    assert expand_ranges(st1["done_ranges"]) == []
    assert len(st1["running"]) == 4              # first wave on-chip
    system.net.run(until=system.net.now + 1.5)   # wave 1 completes
    st2 = express(status_name)["data"].json()
    assert st2["state"] == "Running"
    assert len(expand_ranges(st2["done_ranges"])) == 4
    assert len(st2["durs"]) == 4                 # p50 samples for the monitor
    system.net.run()
    st3 = express(status_name)["data"].json()
    assert st3["state"] == "Completed"
    assert expand_ranges(st3["done_ranges"]) == list(range(8))
    assert st3["failed"] == {}


def test_batch_multi_status_reports_unknown():
    system, _ = fleet()
    express = Express(system)
    receipt = express(batch_job_name(template(), 0, 4))["data"].json()
    bid = receipt["batch_id"]
    name = Name.parse(STATUS_PREFIX).append(
        "tmpod0", "batch", f"ids={bid},deadbeef0000")
    payload = express(name)["data"].json()
    assert payload["batches"][bid]["state"] in ("Running", "Completed")
    assert payload["batches"]["deadbeef0000"]["state"] == "Unknown"


def test_job_multi_status_coalesces_and_reports_unknown():
    system, _ = fleet(chips=2)
    express = Express(system)
    receipt = express(batch_job_name(template(cost="2.0"), 0, 4))[
        "data"].json()
    cluster = system.overlay.clusters["tmpod0"]
    jids = sorted(cluster.jobs)
    name = Name.parse(STATUS_PREFIX).append(
        "tmpod0", "ids=" + ",".join(jids + ["bogus"]))
    payload = express(name)["data"].json()
    jobs = payload["jobs"]
    assert set(jobs) == set(jids) | {"bogus"}
    assert jobs["bogus"]["state"] == "Unknown"
    states = {jobs[j]["state"] for j in jids}
    assert states <= {"Pending", "Running"}
    # every non-terminal member quotes an ETA (queued ones from the one
    # shared timeline replay)
    assert all("eta" in jobs[j] for j in jids)
    assert receipt["admitted"] == 4


def test_cached_members_bypass_scheduler():
    """Parts whose canonical result is already in the lake are answered
    from the §VII cache: admitted only the rest, cached= names them."""
    system, log = fleet(chips=8)
    express = Express(system)
    name = batch_job_name(template(cost="0.01"), 0, 8)
    express(name)
    system.net.run()
    first_total = log.total
    assert first_total == 8
    # same template, wider range: 0..8 are cache hits, 8 new parts run.
    # (8 segs only — parts 8.. read nothing; keep range at 8 and instead
    # re-express the identical batch after completion)
    system.net.run(until=system.net.now + 2.0)
    gw = system.overlay.gateways["tmpod0"]
    shortcuts_before = gw.cache_shortcuts
    # evict the batch record to force a fresh cache scan
    gw._batches.clear()
    gw._batch_member.clear()
    receipt = express(name)["data"].json()
    assert receipt["state"] == "Completed"
    assert expand_ranges(receipt["cached"]) == list(range(8))
    assert receipt["admitted"] == 0
    assert gw.cache_shortcuts == shortcuts_before + 8
    system.net.run()
    assert log.total == first_total              # nothing re-executed
