"""The windowed segment pipeline: fetcher vs oracle, AIMD, zero-copy.

The heart of the data plane's correctness story: whatever the network
does — loss, reordering across unequal replica paths, window collapse —
the bytes a :class:`SegmentFetcher` delivers must be identical to the
:meth:`DataLake.get_bytes` oracle, deterministically on the virtual
clock.
"""

import random

import numpy as np
import pytest

from repro.core.forwarder import Consumer, Forwarder, Network, link
from repro.core.names import Name
from repro.core.packets import Interest
from repro.core.strategy import AdaptiveStrategy
from repro.datalake import DataLake, MemoryStore, SegmentFetcher, fetch

SEG = 1024


def blob_of(size: int, seed: int) -> bytes:
    return np.random.default_rng(seed).integers(
        0, 256, size, dtype=np.uint8).tobytes()


def build_plane(n_replicas=2, *, seg=SEG, loss=0.0, seed=0,
                latencies=(0.001, 0.004, 0.002, 0.006)):
    """client — edge — N replica gateways with unequal path latencies
    (unequal paths + a window = natural segment reordering)."""
    net = Network()
    client = Forwarder(net, "client",
                       strategy=AdaptiveStrategy(probe_fanout=1))
    edge = Forwarder(net, "edge", strategy=AdaptiveStrategy(probe_fanout=1))
    cf, _ = link(net, client, edge, 0.0005)
    client.register_route(Name.parse("/lidc/data"), cf)
    lakes = []
    for i in range(n_replicas):
        gw = Forwarder(net, f"gw{i}")
        fe, fg = link(net, edge, gw, latencies[i % len(latencies)])
        if loss:
            fg.loss = loss
            fg.loss_rng = random.Random(seed * 1000 + i)
        lake = DataLake(segment_size=seg)
        lake.attach(gw)
        edge.register_route(Name.parse("/lidc/data"), fe)
        lakes.append(lake)
    return net, client, lakes


def publish(lakes, name, blob):
    for lake in lakes:
        lake.put_bytes(name, blob)


def test_multi_segment_reassembly_matches_oracle():
    net, client, lakes = build_plane()
    name = Name.parse("/lidc/data/obj")
    blob = blob_of(10 * SEG + 17, 1)
    publish(lakes, name, blob)
    f = fetch(net, client, name, verify_key=lakes[0].key)
    assert f.state == "done", f.error
    assert f.result == lakes[0].get_bytes(name) == blob
    assert f.stats["segments"] == 11


def test_small_object_single_fetch_fallback():
    net, client, lakes = build_plane()
    name = Name.parse("/lidc/data/small")
    publish(lakes, name, b"tiny payload")
    f = fetch(net, client, name)
    assert f.state == "done" and f.result == b"tiny payload"
    assert f.stats["segments"] == 0          # no windowed phase ran


def test_missing_object_fails_cleanly():
    net, client, lakes = build_plane()
    f = fetch(net, client, Name.parse("/lidc/data/absent"))
    assert f.state == "failed" and f.error is not None


def test_zero_copy_on_put_and_serve():
    net, client, lakes = build_plane(n_replicas=1)
    name = Name.parse("/lidc/data/zc")
    blob = blob_of(8 * SEG, 2)
    publish(lakes, name, blob)
    f = fetch(net, client, name)
    assert f.result == blob
    for lake in lakes:
        assert isinstance(lake.store, MemoryStore)
        assert lake.store.copies == 0        # no bytes() on put or serve


def test_window_split_spreads_across_replicas():
    net, client, lakes = build_plane(n_replicas=3, latencies=(0.001,) * 3)
    name = Name.parse("/lidc/data/spread")
    publish(lakes, name, blob_of(30 * SEG, 3))
    f = fetch(net, client, name, init_cwnd=6)
    assert f.result is not None
    serves = [lake.segment_serves for lake in lakes]
    assert all(s > 0 for s in serves), serves   # every replica pulled weight


def test_loss_triggers_multiplicative_decrease_and_recovery():
    net, client, lakes = build_plane(loss=0.15, seed=5)
    name = Name.parse("/lidc/data/lossy")
    blob = blob_of(20 * SEG, 4)
    publish(lakes, name, blob)
    f = fetch(net, client, name)
    assert f.state == "done" and f.result == blob
    assert f.stats["retransmissions"] > 0
    assert f.stats["window_decreases"] > 0
    mds = [c for _, c, e in f.trace if e.startswith("md")]
    assert mds, "no multiplicative-decrease event in the window trace"


def test_fetch_is_deterministic_on_the_virtual_clock():
    runs = []
    for _ in range(2):
        net, client, lakes = build_plane(loss=0.1, seed=9)
        name = Name.parse("/lidc/data/det")
        publish(lakes, name, blob_of(12 * SEG + 5, 6))
        f = fetch(net, client, name)
        assert f.state == "done"
        runs.append(f.trace)
    assert runs[0] == runs[1]   # same seed -> byte-identical window trace


def test_second_consumer_served_from_intermediate_cs():
    net, client, lakes = build_plane(n_replicas=2, latencies=(0.001, 0.001))
    name = Name.parse("/lidc/data/popular")
    blob = blob_of(16 * SEG, 7)
    publish(lakes, name, blob)
    f1 = fetch(net, client, name)
    assert f1.result == blob
    served_before = sum(lake.segment_serves for lake in lakes)
    f2 = fetch(net, client, name)
    assert f2.result == blob
    # the replicas saw (almost) nothing of the second fetch
    assert sum(lake.segment_serves for lake in lakes) == served_before


def test_rto_seeds_from_nexthop_telemetry():
    net, client, lakes = build_plane()
    name = Name.parse("/lidc/data/warm")
    publish(lakes, name, blob_of(4 * SEG, 8))
    # warm the per-face RTT telemetry with an ordinary fetch
    Consumer(net, client).get(name.append("manifest"))
    f = SegmentFetcher(net, client, name)
    assert f._srtt is not None and f._srtt > 0


@pytest.mark.parametrize("size", [0, 1, SEG - 1, SEG, SEG + 1,
                                  3 * SEG, 3 * SEG + 1])
def test_boundary_sizes_round_trip(size):
    net, client, lakes = build_plane()
    name = Name.parse(f"/lidc/data/b{size}")
    blob = blob_of(size, size)
    publish(lakes, name, blob)
    f = fetch(net, client, name, verify_key=lakes[0].key)
    assert f.state == "done", f.error
    assert f.result == blob == lakes[0].get_bytes(name)


def test_transient_no_route_retries_instead_of_monolithic_downgrade():
    """A no-route Nack mid-churn is transient: the fetcher must keep
    retrying manifest discovery (and go windowed once routing heals),
    not permanently downgrade a segmented object to one monolithic Data."""
    net = Network()
    client = Forwarder(net, "client", strategy=AdaptiveStrategy(probe_fanout=1))
    gw = Forwarder(net, "gw")
    cf, _ = link(net, client, gw, 0.001)
    lake = DataLake(segment_size=SEG)
    lake.attach(gw)
    name = Name.parse("/lidc/data/late-route")
    blob = blob_of(6 * SEG, 11)
    lake.put_bytes(name, blob)
    f = SegmentFetcher(net, client, name).start()   # no route yet -> Nacks
    net.schedule(0.5, lambda: client.register_route(
        Name.parse("/lidc/data"), cf))              # routing converges
    net.run()
    assert f.state == "done" and f.result == blob
    assert f.stats["segments"] == 6                 # windowed, not monolithic
    assert f.stats["nacks"] > 0


def test_fetch_releases_its_auto_created_consumer_face():
    net, client, lakes = build_plane()
    name = Name.parse("/lidc/data/loop")
    publish(lakes, name, blob_of(4 * SEG, 12))
    fetch(net, client, name)                        # prime (also a fetch)
    n_faces = len(client.faces)
    for _ in range(5):
        assert fetch(net, client, name).state == "done"
    assert len(client.faces) == n_faces             # no per-fetch face leak


def test_ambiguous_manifest_is_refused_not_corrupted():
    """A multi-segment manifest without segment_size can't place offsets
    safely — the fetcher must fail loudly, never reassemble a guess."""
    import json
    net, client, lakes = build_plane(n_replicas=1)
    lake = lakes[0]
    base = "/lidc/data/legacy"
    for i, chunk in enumerate((b"aaaa", b"bbbb", b"c")):   # 4+4+1 = 9 bytes
        lake.store.put(f"{base}/seg={i}", chunk)
    lake.store.put(f"{base}/manifest",
                   json.dumps({"segments": 3, "size": 9}).encode())
    f = fetch(net, client, Name.parse(base))
    assert f.state == "failed" and "manifest-malformed" in f.error


def test_property_reassembly_matches_oracle_under_faults():
    pytest.importorskip("hypothesis", reason="property sweep needs hypothesis")
    from hypothesis import given, settings, strategies as st

    sizes = st.one_of(
        st.sampled_from([1, SEG - 1, SEG, SEG + 1, 2 * SEG, 4 * SEG + 3]),
        st.integers(0, 5 * SEG))

    @settings(max_examples=20, deadline=None)
    @given(size=sizes, loss=st.floats(0.0, 0.25), seed=st.integers(0, 2 ** 16))
    def check(size, loss, seed):
        net, client, lakes = build_plane(loss=loss, seed=seed)
        name = Name.parse("/lidc/data/prop")
        blob = blob_of(size, seed)
        publish(lakes, name, blob)
        f = fetch(net, client, name, verify_key=lakes[0].key)
        assert f.state == "done", (size, loss, seed, f.error)
        assert f.result == lakes[0].get_bytes(name) == blob

    check()


def test_quiescent_forwarder_records_timeout_outcomes():
    """Pit.expire rides a scheduled tick: a producer that goes silent is
    reported to the strategy even if no later Interest ever arrives."""
    net = Network()
    a = Forwarder(net, "a", strategy=AdaptiveStrategy(probe_fanout=1))
    b = Forwarder(net, "b")
    fa, _ = link(net, a, b)
    a.register_route(Name.parse("/x"), fa)
    b.attach_producer(Name.parse("/x"), lambda i, pub, now: None)  # silence
    failures = []
    a.strategy.feedback = lambda name, face, ok, rtt, now: \
        failures.append(ok) if not ok else None
    Consumer(net, a).express(
        Interest(name=Name.parse("/x/q"), lifetime=0.5),
        on_data=lambda d: None, retries=0)
    net.run()
    assert len(a.pit) == 0                  # the entry expired off the tick
    assert failures, "timeout outcome never reached the strategy"
    hop = a.fib.nexthops(Name.parse("/x")).get(fa.face_id)
    assert hop is not None and hop.failures >= 1 and hop.pending == 0
