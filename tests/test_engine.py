"""Event-engine equivalence: the calendar queue is a pure speedup.

The calendar-queue engine must be *behaviorally invisible*: any seeded
scenario produces the identical ``(time, seq)`` event trace, final
virtual clock and delivery outcome as the original global-heap engine —
and windowed execution (``run(until=...)`` / ``run(max_events=...)``
chunking, which tests and long-lived drivers use) must be invisible on
both engines.  Plus the PIT expiry-heap compaction regression: the lazy
min-heap must stay bounded under retransmission churn.
"""

import random

import pytest

from repro.core.forwarder import Network
from repro.core.names import Name
from repro.core.overlay import MeshTopology
from repro.core.packets import Data, Interest
from repro.core.tables import Pit

# ---------------------------------------------------------------------------
# raw queue-order equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 7])
def test_random_schedule_identical_order(seed):
    """Randomized delays, nested re-scheduling: both engines execute the
    exact same (time, seq) sequence."""
    traces = {}
    for engine in ("heap", "calendar"):
        rng = random.Random(seed)
        net = Network(engine=engine)
        net.trace = []
        executed = []

        def fire(depth=0):
            executed.append(net.now)
            if depth < 3 and rng.random() < 0.5:
                # bimodal: packet-scale or heartbeat-scale
                delay = (rng.uniform(0, 0.004) if rng.random() < 0.8
                         else rng.uniform(0.5, 3.0))
                net.schedule(delay, lambda d=depth: fire(d + 1))

        for _ in range(200):
            net.schedule(rng.uniform(0, 5.0), fire)
        net.run()
        traces[engine] = (net.trace, executed, net.now)
    assert traces["heap"] == traces["calendar"]


def test_calendar_push_into_parked_window():
    """A run(until=...) horizon can park the clock before the head event's
    bucket; a later near-term push must still pop in time order."""
    net = Network(engine="calendar", bucket_width=0.005)
    order = []
    net.schedule(0.012, lambda: order.append("far"))
    net.run(until=0.001)           # horizon short of the head event
    assert net.now == 0.001
    net.schedule(0.002, lambda: order.append("near"))   # t=0.003 < 0.012
    net.run()
    assert order == ["near", "far"]


# ---------------------------------------------------------------------------
# whole-system seeded equivalence
# ---------------------------------------------------------------------------

def _run_mesh_scenario(engine, kind, seed, *, chunker=None):
    """A small mesh + producers + consumer scenario; returns the full
    behavior capture.  ``chunker`` (if given) replaces each ``run`` call
    with an equivalent sequence of windowed runs."""
    net = Network(engine=engine)
    net.trace = []
    mesh = MeshTopology(net, 9, kind, seed=seed)
    prefixes = []
    for i in range(6):
        prefix = Name.parse("/svc").append(f"p{i}")
        mesh.attach_producer(
            i, prefix,
            lambda interest, publish, now: Data(
                name=interest.name, content=b"x", created_at=now,
                freshness=30.0))
        prefixes.append(prefix)

    def run(until=None):
        if chunker is not None:
            chunker(net, until)
        elif until is not None:
            net.run(until=until)
        else:
            net.run()

    run(until=2.0)                 # converge on the virtual clock
    rng = random.Random(seed + 1)
    consumer = mesh.consumer_at(8)
    delivered = []
    for i in range(40):
        p = prefixes[rng.randrange(len(prefixes))]

        def express(name=p.append(f"j{i}")):
            consumer.express(
                Interest(name=name, lifetime=1.0, hop_limit=32),
                on_data=lambda d: delivered.append(str(d.name)),
                retries=2)

        net.schedule(i * 0.03, express)
    run()                          # drain to quiescence
    return net.trace, net.now, delivered, net.events_processed


@pytest.mark.parametrize("kind", ["ring", "tree", "random"])
def test_engines_identical_system_traces(kind):
    heap_cap = _run_mesh_scenario("heap", kind, seed=3)
    cal_cap = _run_mesh_scenario("calendar", kind, seed=3)
    assert heap_cap == cal_cap
    assert len(heap_cap[2]) == 40      # everything delivered, both engines


# ---------------------------------------------------------------------------
# run() chunking is invisible (both engines)
# ---------------------------------------------------------------------------

def _chunker(seed):
    """Replays a run() as randomized (until, max_events) windows."""
    rng = random.Random(seed)

    def chunk(net, until):
        if until is not None:
            while net.now < until:
                net.run(until=min(net.now + rng.uniform(0.01, 0.4), until),
                        max_events=rng.choice([1, 3, 17, 1000]))
            net.run(until=until)   # drain events at exactly the horizon
        else:
            while not net.idle():
                net.run(max_events=rng.choice([1, 2, 29, 500]))
    return chunk


@pytest.mark.parametrize("engine", ["heap", "calendar"])
@pytest.mark.parametrize("seed", [11, 23])
def test_chunked_run_invisible(engine, seed):
    """Interrupting run() at arbitrary (until, max_events) boundaries must
    not change the event order, the final clock, or what got delivered."""
    whole = _run_mesh_scenario(engine, "ring", seed=seed)
    chunked = _run_mesh_scenario(engine, "ring", seed=seed,
                                 chunker=_chunker(seed))
    assert whole == chunked


def test_chunked_run_identical_across_engines():
    """Chunking AND engine choice together: all four executions agree."""
    caps = [_run_mesh_scenario(engine, "tree", seed=5, chunker=ch)
            for engine in ("heap", "calendar")
            for ch in (None, _chunker(5))]
    assert all(c == caps[0] for c in caps[1:])


# ---------------------------------------------------------------------------
# PIT expiry-heap compaction under retransmission churn
# ---------------------------------------------------------------------------

def _heap_bound(pit):
    return max(pit._COMPACT_MIN,
               pit._COMPACT_FACTOR * (len(pit) + 1)) + 1


def test_pit_heap_bounded_under_retransmission_churn():
    """A few hot names retransmitted thousands of times: every extension
    pushes a stale heap record, and without compaction the heap grows
    without bound while the PIT itself holds 4 entries."""
    pit = Pit()
    names = [Name.parse(f"/job/hot{i}") for i in range(4)]
    now = 0.0
    for round_ in range(2000):
        now += 0.01
        for name in names:
            # fresh nonce every time -> aggregation path, expiry extended
            pit.insert(Interest(name=name, lifetime=4.0), in_face=1, now=now)
    assert len(pit) == 4
    assert pit.compactions > 0
    assert len(pit._expiry_heap) <= _heap_bound(pit)


def test_pit_heap_bounded_under_satisfy_churn():
    """Insert-then-satisfy churn: satisfied entries leave tombstones that
    compaction (not just lazy pops at expiry time) must reclaim."""
    pit = Pit()
    now = 0.0
    for i in range(5000):
        now += 0.001
        name = Name.parse("/flow").append(f"s{i}")
        pit.insert(Interest(name=name, lifetime=60.0), in_face=1, now=now)
        if i % 8:                  # satisfy most, keep a slowly-growing tail
            pit.satisfy(name)
    assert len(pit._expiry_heap) <= _heap_bound(pit)
    # lazy expiry still works after compactions
    assert pit.next_expiry() is not None
    assert pit.expire(now + 120.0)
    assert len(pit) == 0


def test_pit_expiry_order_survives_compaction():
    """Compaction must not change what expires when."""
    pit = Pit()
    n0 = Name.parse("/a")
    pit.insert(Interest(name=n0, lifetime=1.0), in_face=1, now=0.0)
    for i in range(500):
        pit.insert(Interest(name=Name.parse(f"/b/{i}"), lifetime=5.0),
                   in_face=1, now=0.0)
        pit.satisfy(Name.parse(f"/b/{i}"))
    assert pit.compactions > 0
    assert pit.next_expiry() == 1.0
    dead = pit.expire(1.0)
    assert [e.name for e in dead] == [n0]
