"""Property tests for partition discovery (hypothesis-only module).

Partition discovery must *tile* a dataset: contiguous segment runs, no
gap, no overlap, byte ranges reassembling the original object exactly —
at every boundary size hypothesis can find.  A deterministic sweep of
the same invariants lives in test_taskmap.py for environments without
hypothesis.
"""

import pytest

from repro.workflow.taskmap import plan_partitions

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

SEG = 64
sizes = st.one_of(
    st.sampled_from([1, SEG - 1, SEG, SEG + 1, 2 * SEG, 5 * SEG - 1,
                     5 * SEG, 5 * SEG + 1, 17 * SEG + 3]),
    st.integers(min_value=1, max_value=40 * SEG))


def n_segments(size: int) -> int:
    # the lake stores objects <= one segment unsegmented
    return -(-size // SEG) if size > SEG else 1


@given(size=sizes, tasks=st.one_of(st.none(),
                                   st.integers(min_value=1, max_value=64)))
@settings(max_examples=200, deadline=None)
def test_partitions_tile_exactly(size, tasks):
    segments = n_segments(size)
    parts = plan_partitions(size=size, segments=segments, segment_size=SEG,
                            tasks=tasks)
    # segment ranges: contiguous, gap-free, total == segments
    assert parts[0].seg_lo == 0
    assert parts[-1].seg_hi == segments
    for a, b in zip(parts, parts[1:]):
        assert a.seg_hi == b.seg_lo
        assert a.seg_hi > a.seg_lo
    # byte ranges: tile [0, size) exactly
    assert parts[0].byte_lo == 0
    assert parts[-1].byte_hi == size
    for a, b in zip(parts, parts[1:]):
        assert a.byte_hi == b.byte_lo
    # part ids are dense 0..n-1 (the result-cache dedupe key)
    assert [p.part for p in parts] == list(range(len(parts)))
    if tasks is not None:
        assert len(parts) <= max(1, min(tasks, segments))


@given(size=st.integers(min_value=1, max_value=20 * SEG))
@settings(max_examples=60, deadline=None)
def test_partitions_reassemble_byte_identical(size):
    """Reading each partition's byte range back to back reproduces the
    original blob byte-for-byte."""
    blob = bytes((i * 37 + 11) % 256 for i in range(size))
    parts = plan_partitions(size=size, segments=n_segments(size),
                            segment_size=SEG)
    pieces = [blob[p.byte_lo:p.byte_hi] for p in parts]
    assert b"".join(pieces) == blob
    assert all(len(pc) > 0 for pc in pieces[:-1])
