"""Workflow DAGs: compilation, scatter–gather execution, result caching.

Everything runs on the deterministic virtual-clock network; cluster
executors log every invocation into an ExecutionLog keyed by job
signature, which is the ground truth for the exactly-once / zero-
execution assertions (a cache-served stage never reaches an executor).
"""

import pytest

from repro.core.jobs import decode_input_names, encode_input_names
from repro.core.names import Name
from repro.core.strategy import AdaptiveStrategy, LoadShareStrategy
from repro.workflow import WorkflowEngine, WorkflowError, WorkflowSpec
from repro.workflow.apps import build_workflow_fleet

DATASET = "/lidc/data/reads/sample"


def blast_spec(dataset: str = DATASET, parts: int = 4, tag: str = "t"
               ) -> WorkflowSpec:
    return (WorkflowSpec(f"blast-{tag}")
            .stage("shard", "wf-shard", inputs=[dataset], parts=parts, tag=tag)
            .stage("align", "wf-align", inputs=["@shard"], fanout=parts,
                   tag=tag)
            .stage("merge", "wf-merge", inputs=["@align"], tag=tag))


def fleet(n=3, strategy=None, data_bytes=128 * 1024):
    system, log = build_workflow_fleet(n, chips=4, strategy=strategy)
    system.lake.put_bytes(Name.parse(DATASET), bytes(range(256)) *
                          (data_bytes // 256))
    return system, log


# ---------------------------------------------------------------------------
# input-name codec
# ---------------------------------------------------------------------------

def test_input_name_codec_round_trips():
    names = [Name.parse("/lidc/data/reads/sample"),
             Name.parse("/lidc/data/results/abc123")]
    enc = encode_input_names(names)
    assert "/" not in enc
    assert decode_input_names(enc) == names
    assert decode_input_names("") == []


def test_input_name_codec_rejects_separator_collisions():
    with pytest.raises(ValueError):
        encode_input_names([Name(("lidc", "data", "a,b"))])
    with pytest.raises(ValueError):
        encode_input_names([Name(("lidc", "data", "k=v&x=y"))])


# ---------------------------------------------------------------------------
# DAG compilation
# ---------------------------------------------------------------------------

def test_compile_orders_and_expands_scatter():
    wf = blast_spec(parts=3).compile()
    ids = list(wf.instances)
    assert ids == ["shard", "align.0", "align.1", "align.2", "merge"]
    merge = wf.instances["merge"]
    assert set(merge.deps) == {"align.0", "align.1", "align.2"}
    # every instance's result name is precomputed and distinct
    rnames = {str(i.result_name) for i in wf.instances.values()}
    assert len(rnames) == len(wf.instances)
    # align inputs are the shard's (single) result name
    for i in range(3):
        inst = wf.instances[f"align.{i}"]
        assert inst.fields["in"] == encode_input_names(
            [wf.instances["shard"].result_name])
        assert inst.fields["part"] == i


def test_compile_is_deterministic():
    a, b = blast_spec().compile(), blast_spec().compile()
    assert list(a.instances) == list(b.instances)
    for i in a.instances:
        assert a.instances[i].request_name == b.instances[i].request_name
        assert a.instances[i].result_name == b.instances[i].result_name


def test_compile_rejects_cycles():
    wf = WorkflowSpec("cyclic")
    wf.stage("a", "wf-merge", inputs=["@b"])
    wf.stage("b", "wf-merge", inputs=["@a"])
    with pytest.raises(WorkflowError, match="cycle"):
        wf.compile()


def test_compile_rejects_unknown_ref_and_dup_and_bad_fanout():
    with pytest.raises(WorkflowError, match="unknown stage"):
        WorkflowSpec("x").stage("a", "wf-merge", inputs=["@ghost"]).compile()
    with pytest.raises(WorkflowError, match="duplicate"):
        WorkflowSpec("x").stage("a", "wf-merge").stage("a", "wf-merge")
    with pytest.raises(WorkflowError, match="fanout"):
        WorkflowSpec("x").stage("a", "wf-merge", fanout=0)
    with pytest.raises(WorkflowError, match="input"):
        WorkflowSpec("x").stage("a", "wf-merge", inputs=["not-a-name"])


def test_compile_rejects_fanout_mismatch():
    wf = WorkflowSpec("mismatch")
    wf.stage("a", "wf-align", fanout=3, inputs=[DATASET])
    wf.stage("b", "wf-align", fanout=2, inputs=["@a"])
    with pytest.raises(WorkflowError, match="element-wise"):
        wf.compile()


def test_scatter_chain_is_element_wise():
    wf = WorkflowSpec("chain")
    wf.stage("a", "wf-align", fanout=2, inputs=[DATASET])
    wf.stage("b", "wf-align", fanout=2, inputs=["@a"])
    compiled = wf.compile()
    for i in range(2):
        b = compiled.instances[f"b.{i}"]
        assert b.deps == (f"a.{i}",)
        assert b.fields["in"] == encode_input_names(
            [compiled.instances[f"a.{i}"].result_name])


# ---------------------------------------------------------------------------
# end-to-end scatter–gather over the overlay
# ---------------------------------------------------------------------------

def test_scatter_gather_completes_exactly_once():
    system, log = fleet(3, strategy=LoadShareStrategy())
    wf = blast_spec(parts=4).compile()
    run = WorkflowEngine(system.net, system.overlay.edge).run(wf)
    assert run.complete and run.failed is None
    assert run.makespan is not None and run.makespan > 0
    # exactly-once: every stage instance reached an executor exactly once
    assert sorted(log.per_signature().values()) == [1] * 6
    assert run.cache_hits == 0 and run.resubmissions == 0
    # the merge saw all four align outputs and the full dataset size
    merge = run.results["merge"]
    assert merge["inputs"] == 4
    assert merge["total_bytes"] == 128 * 1024
    assert merge["best_score"] > 0


def test_scatter_spreads_across_clusters():
    system, log = fleet(
        4, strategy=AdaptiveStrategy(probe_fanout=1, rotate_cold_probes=True))
    run = WorkflowEngine(system.net, system.overlay.edge).run(
        blast_spec(parts=4).compile())
    assert run.complete
    # cold-probe rotation places the scatter instances on distinct clusters
    align_clusters = {c for _, app, c, _ in log.events if app == "wf-align"}
    assert len(align_clusters) >= 3, log.events


def test_identical_stages_dedup_within_workflow():
    system, log = fleet(3, strategy=LoadShareStrategy())
    wf = WorkflowSpec("dedup")
    wf.stage("shard", "wf-shard", inputs=[DATASET], parts=2)
    # two logical stages with byte-identical fields -> one canonical name
    wf.stage("m1", "wf-merge", inputs=["@shard"])
    wf.stage("m2", "wf-merge", inputs=["@shard"])
    compiled = wf.compile()
    assert (compiled.instances["m1"].request_name
            == compiled.instances["m2"].request_name)
    run = WorkflowEngine(system.net, system.overlay.edge).run(compiled)
    assert run.complete
    # the duplicate stage aggregated onto the first: one merge execution
    assert sorted(log.per_signature().values()) == [1, 1]


def test_identical_workflow_twice_is_fully_cache_served():
    """Satellite: second submission completes with ZERO cluster executions."""
    system, log = fleet(3, strategy=LoadShareStrategy())
    wf = blast_spec(parts=4).compile()
    run1 = WorkflowEngine(system.net, system.overlay.edge).run(wf)
    assert run1.complete and log.total == 6

    run2 = WorkflowEngine(system.net, system.overlay.edge).run(
        blast_spec(parts=4).compile())
    assert run2.complete
    assert log.total == 6, "second run must not reach any executor"
    assert run2.cache_hits == len(run2.workflow)
    assert run2.makespan < run1.makespan
    # same digest-derived names -> same results, served from the lake/CS
    assert run2.results["merge"]["best_score"] == \
        run1.results["merge"]["best_score"]


def test_shared_subworkflow_dedups_across_workflows():
    """A workflow reusing another's sub-computation skips re-executing it."""
    system, log = fleet(3, strategy=LoadShareStrategy())
    run1 = WorkflowEngine(system.net, system.overlay.edge).run(
        blast_spec(parts=4).compile())
    assert run1.complete and log.total == 6

    # same shard+align sub-DAG, different terminal stage params
    wf2 = (WorkflowSpec("blast-roc")
           .stage("shard", "wf-shard", inputs=[DATASET], parts=4, tag="t")
           .stage("align", "wf-align", inputs=["@shard"], fanout=4, tag="t")
           .stage("merge", "wf-merge", inputs=["@align"], tag="different"))
    run2 = WorkflowEngine(system.net, system.overlay.edge).run(wf2.compile())
    assert run2.complete
    # only the new merge executed; shard+aligns were cache hits
    assert log.total == 7
    assert run2.cache_hits == 5


# ---------------------------------------------------------------------------
# compute-plane integration: priorities + busy receipts
# ---------------------------------------------------------------------------

def test_workflow_priority_is_inherited_by_stages():
    wf = (WorkflowSpec("urgent", priority=3)
          .stage("shard", "wf-shard", inputs=[DATASET], parts=2, tag="p")
          .stage("align", "wf-align", inputs=["@shard"], fanout=2, tag="p",
                 prio=7))                      # per-stage override wins
    compiled = wf.compile()
    shard = compiled.instances["shard"]
    assert shard.fields["prio"] == 3
    assert "prio=3" in str(shard.request_name)
    for i in range(2):
        assert compiled.instances[f"align.{i}"].fields["prio"] == 7
    # priority is part of the canonical name: the same work at another
    # priority is a different request (and a different cache entry)
    other = (WorkflowSpec("calm")
             .stage("shard", "wf-shard", inputs=[DATASET], parts=2, tag="p")
             .compile())
    assert str(other.instances["shard"].request_name) != \
        str(shard.request_name)


def test_engine_backs_off_on_busy_receipts_and_recovers():
    """With the whole (single-cluster) fleet saturated, submits fail as
    ``nack:busy``; the engine retries on a backoff without burning its
    crash-recovery attempts and completes once chips free up."""
    system, log = fleet(1)
    cluster = next(iter(system.overlay.clusters.values()))
    # occupy every chip for 20 virtual seconds
    from repro.core.cluster import ExecResult
    from repro.core.jobs import JobSpec
    from repro.core.matchmaker import ServiceEndpoint
    cluster.add_endpoint(ServiceEndpoint(
        service="hog.svc", app="hog",
        executor=lambda job, cl: ExecResult(payload={}, duration=20.0)))
    cluster.submit(JobSpec(app="hog", fields={"chips": cluster.chips}),
                   now=0.0)
    assert cluster.free_chips == 0
    eng = WorkflowEngine(system.net, system.overlay.edge)
    run = eng.run(blast_spec(parts=2, tag="busy").compile())
    assert run.complete, run.stage_report()
    busy_failures = [e for e in run.trace
                     if e[1] == "submit-fail" and "busy" in e[3]]
    assert busy_failures, "saturation never surfaced as a busy receipt"
    shard = run.stages["shard"]
    assert shard.busy_retries >= 1
    assert run.finished_at > 20.0          # completed after the hog drained


def test_engine_coalesces_status_polls_per_cluster():
    """A wide scatter parked on one saturated cluster polls with ONE
    ``ids=`` Interest per cluster per cadence, not one per stage — the
    status-poll amplification fix."""
    system, log = fleet(1)
    eng = WorkflowEngine(system.net, system.overlay.edge)
    run = eng.run(blast_spec(parts=6, tag="coal").compile())
    assert run.complete and run.failed is None
    assert sorted(log.per_signature().values()) == [1] * 8
    # the 6-wide align layer polls concurrently: coalescing must answer
    # strictly fewer status Interests than poll cycles requested
    assert eng.stage_polls > 0
    assert eng.status_interests < eng.stage_polls
