"""Elastic map fan-out: partition tiling, batched fan-out, speculation.

The tiling property test pins the tentpole's correctness foundation:
manifest-driven partition discovery must cover a dataset exactly — no
gap, no overlap, byte-identical reassembly — at every boundary size,
because every downstream guarantee (exactly-once via ``part=i`` result
names, reduce correctness) assumes the tiles are a partition in the
mathematical sense.

The speculation tests pin the exactly-once contract both ways: a
speculative duplicate that *loses* the race is absorbed by the result
cache (``log.reexecuted() == {}``), and a duplicate that *wins* against
a time-dilated straggler is counted as a speculation win without
breaking delivery.
"""

import pytest

from repro.core.jobs import INPUTS_FIELD, JobSpec, encode_input_names
from repro.core.names import DATA_PREFIX, Name
from repro.workflow.taskmap import (TaskMapExecutor, build_taskmap_fleet,
                                    plan_partitions)

# a 64-byte record: segment sizes that divide into records keep words
# from spanning segment boundaries, so wordcount is exact
RECORD = b"alpha bravo charlie delta echo foxtrot golf hotel indigo juliet "
WORDS_PER_RECORD = 10
DATASET = Name.parse(DATA_PREFIX).append("text", "corpus")


def fleet(n=3, *, chips=4, segment_size=256, records=64, **kw):
    system, log = build_taskmap_fleet(n, chips=chips,
                                      segment_size=segment_size, **kw)
    blob = RECORD * records
    system.lake.put_bytes(DATASET, blob)
    system.net.run(until=system.net.now + 5)      # let routes gossip
    return system, log, len(blob)


# ---------------------------------------------------------------------------
# partition discovery tiles exactly (deterministic sweep; the hypothesis
# version of these invariants lives in test_taskmap_properties.py)
# ---------------------------------------------------------------------------

SEG = 64


def n_segments(size: int) -> int:
    # the lake stores objects <= one segment unsegmented
    return -(-size // SEG) if size > SEG else 1


BOUNDARY_SIZES = [1, SEG - 1, SEG, SEG + 1, 2 * SEG, 5 * SEG - 1, 5 * SEG,
                  5 * SEG + 1, 17 * SEG + 3, 40 * SEG]


@pytest.mark.parametrize("size", BOUNDARY_SIZES)
@pytest.mark.parametrize("tasks", [None, 1, 3, 7, 64])
def test_partitions_tile_exactly(size, tasks):
    segments = n_segments(size)
    parts = plan_partitions(size=size, segments=segments, segment_size=SEG,
                            tasks=tasks)
    # segment ranges: contiguous, gap-free, total == segments
    assert parts[0].seg_lo == 0
    assert parts[-1].seg_hi == segments
    for a, b in zip(parts, parts[1:]):
        assert a.seg_hi == b.seg_lo
        assert a.seg_hi > a.seg_lo
    # byte ranges: tile [0, size) exactly
    assert parts[0].byte_lo == 0
    assert parts[-1].byte_hi == size
    for a, b in zip(parts, parts[1:]):
        assert a.byte_hi == b.byte_lo
    # part ids are dense 0..n-1 (the result-cache dedupe key)
    assert [p.part for p in parts] == list(range(len(parts)))
    if tasks is not None:
        assert len(parts) <= max(1, min(tasks, segments))


@pytest.mark.parametrize("size", BOUNDARY_SIZES)
def test_partitions_reassemble_byte_identical(size):
    """Reading each partition's byte range back to back reproduces the
    original blob byte-for-byte."""
    blob = bytes((i * 37 + 11) % 256 for i in range(size))
    parts = plan_partitions(size=size, segments=n_segments(size),
                            segment_size=SEG)
    pieces = [blob[p.byte_lo:p.byte_hi] for p in parts]
    assert b"".join(pieces) == blob
    assert all(len(pc) > 0 for pc in pieces[:-1])


# ---------------------------------------------------------------------------
# end-to-end map / map_reduce
# ---------------------------------------------------------------------------

def test_map_end_to_end_exactly_once():
    system, log, size = fleet(3)
    tm = TaskMapExecutor.for_system(system, batch_size=4)
    run = tm.map("wordcount", DATASET)
    assert run.failed is None and run.complete
    assert run.delivery == 1.0
    assert run.tasks == size // 256
    # ground truth: every task executed exactly once, nothing twice
    assert log.total == run.tasks
    assert log.reexecuted() == {}
    # batched submission + coalesced polling: protocol traffic is far
    # below one Interest per task
    assert tm.submit_interests < run.tasks
    assert tm.status_interests < run.tasks


def test_map_reduce_and_second_run_fully_cached():
    system, log, size = fleet(3)
    records = size // len(RECORD)
    tm = TaskMapExecutor.for_system(system, batch_size=4)
    run = tm.map_reduce("wordcount", "wordcount-reduce", DATASET)
    assert run.failed is None and run.complete
    assert run.reduce_result is not None
    assert run.reduce_result["count"] == records * WORDS_PER_RECORD
    executed = log.total
    assert executed == run.tasks + 1          # maps + one reduce
    # identical map_reduce again: every part AND the reduce are served
    # from the result cache — zero new executions
    run2 = tm.map_reduce("wordcount", "wordcount-reduce", DATASET)
    assert run2.failed is None and run2.complete
    assert run2.reduce_result["count"] == records * WORDS_PER_RECORD
    assert log.total == executed


def test_unsegmented_dataset_single_task():
    # 512 B <= segment_size: stored unsegmented, no manifest — discovery
    # falls back to fetching the object itself and plans one task
    system, log, _ = fleet(3, segment_size=1 << 20, records=8)
    tm = TaskMapExecutor.for_system(system)
    run = tm.map("wordcount", DATASET)
    assert run.failed is None and run.complete
    assert run.tasks == 1
    assert log.total == 1


# ---------------------------------------------------------------------------
# speculation: exactly-once both ways
# ---------------------------------------------------------------------------

def test_speculative_duplicate_never_double_executes():
    """A duplicate that cannot win (the only other cluster is drained)
    bounces off avoided/busy receipts until the original finishes, then
    is absorbed by the result cache: zero re-executions, zero wins."""
    system, log, size = fleet(2, chips=4, records=32)
    system.overlay.clusters["tmpod1"].advertise(chips=0)   # drained
    system.net.run(until=system.net.now + 5)
    tm = TaskMapExecutor.for_system(
        system, batch_size=8,
        speculation=True, spec_factor=0.4, spec_min_samples=2)
    run = tm.map("wordcount", DATASET, cost=1.0)
    assert run.failed is None and run.complete
    assert run.delivery == 1.0
    # the second on-chip wave ages past 0.4 x p50 and is speculated ...
    assert run.speculated, "expected the second wave to be speculated"
    # ... but the duplicates execute nowhere: the home cluster answers
    # avoid= with busy, and by the time they retry the original's result
    # is cached — exactly-once effective execution
    assert log.reexecuted() == {}
    assert log.total == run.tasks
    assert run.spec_wins == 0
    assert log.clusters_used() == ["tmpod0"]


def test_speculation_beats_time_dilated_straggler():
    """A gray-slow cluster (time_dilation) holds its tasks on-chip 10x
    longer than predicted; the monitor speculates them toward the
    healthy cluster, which finishes first — speculation wins, delivery
    stays 1.0, and executed-task amplification stays bounded."""
    system, log, size = fleet(2, chips=8, records=64)     # 16 tasks
    tm = TaskMapExecutor.for_system(
        system, batch_size=4,
        speculation=True, spec_factor=2.0, spec_min_samples=2)
    system.overlay.clusters["tmpod1"].time_dilation = 10.0
    run = tm.map("wordcount", DATASET, cost=2.0)
    assert run.failed is None and run.complete
    assert run.delivery == 1.0
    assert len(log.clusters_used()) == 2      # fan-out hit both clusters
    assert run.spec_wins >= 1
    # at most one duplicate execution per speculated part
    assert log.total <= run.tasks + len(run.speculated)
    # a dilated 2 s task holds its chip for 20 s; the wins keep the map's
    # completion well under that
    assert run.makespan < 20.0


def test_speculation_disabled_waits_out_straggler():
    system, log, size = fleet(2, chips=8, records=64)
    tm = TaskMapExecutor.for_system(system, batch_size=4, speculation=False)
    system.overlay.clusters["tmpod1"].time_dilation = 10.0
    run = tm.map("wordcount", DATASET, cost=2.0)
    assert run.failed is None and run.complete
    assert run.spec_wins == 0 and not run.speculated
    assert log.total == run.tasks             # strict exactly-once
    assert len(log.clusters_used()) == 2
    assert run.makespan >= 20.0               # paid the dilation in full


# ---------------------------------------------------------------------------
# saturation + crash recovery
# ---------------------------------------------------------------------------

def test_batch_busy_backoff_until_chip_frees():
    """A fully occupied cluster with no queue budget answers the batch
    with a busy receipt; the client backs off and the map completes once
    the chip frees."""
    system, log, size = fleet(1, chips=1, records=16, max_queue_depth=0)
    cluster = system.overlay.clusters["tmpod0"]
    # occupy the only chip for 2 virtual seconds
    blocker = JobSpec(app="tm-map", fields={
        "fn": "wordcount", "part": "0", "segs": "4", "spt": "4",
        "cost": "2.0", "blocker": "1",
        INPUTS_FIELD: encode_input_names([DATASET])})
    cluster.submit(blocker, system.net.now)
    assert cluster.free_chips == 0
    tm = TaskMapExecutor.for_system(system, batch_size=4)
    run = tm.map("wordcount", DATASET, cost=0.01)
    assert run.failed is None and run.complete
    assert run.delivery == 1.0
    assert system.overlay.gateways["tmpod0"].busy_receipts > 0


def test_crash_recovery_reexpresses_batch():
    """Kill the cluster holding a batch mid-run: its status goes dark,
    the canonical batch name is re-expressed, and the survivor re-runs
    the lost work."""
    system, log, size = fleet(2, chips=8, records=32)     # 8 tasks
    tm = TaskMapExecutor.for_system(system, batch_size=16,
                                    speculation=False)
    run = tm.start_map("wordcount", DATASET, cost=2.0)
    system.net.run(until=system.net.now + 1.0)    # batch admitted
    victims = {b.cluster for b in run.batches if b.cluster is not None}
    assert len(victims) == 1                  # one batch, one home
    system.overlay.fail_cluster(victims.pop())
    system.net.run()
    assert run.failed is None and run.complete
    assert run.delivery == 1.0
    # crash recovery re-ran the in-flight tasks on the survivor — at
    # most one re-execution per task, never more
    assert all(n == 2 for n in log.reexecuted().values())
