"""Names, the semantic job codec, and NDN prefix semantics."""

import pytest

from repro.core.names import (COMPUTE_PREFIX, Name, canonical_job_name,
                              encode_job, job_fields_of, parse_job)


def test_parse_and_str_roundtrip():
    n = Name.parse("/lidc/compute/train/qwen3-1.7b")
    assert str(n) == "/lidc/compute/train/qwen3-1.7b"
    assert len(n) == 4
    assert n[0] == "lidc"


def test_component_prefix_semantics():
    # NDN prefixes are component-wise, not substring-wise
    assert Name.parse("/lidc/comp").is_prefix_of(Name.parse("/lidc/compute")) \
        is False
    assert Name.parse("/lidc").is_prefix_of(Name.parse("/lidc/compute"))
    assert Name.parse("/lidc/compute").is_prefix_of(
        Name.parse("/lidc/compute"))


def test_append_and_truediv():
    n = Name.parse("/a") / "b"
    assert str(n.append("c", "d")) == "/a/b/c/d"


def test_illegal_names():
    with pytest.raises(ValueError):
        Name.parse("no-slash")
    with pytest.raises(ValueError):
        Name.parse("/bad component with spaces")


def test_job_codec_roundtrip():
    fields = {"app": "train", "arch": "qwen2-0.5b", "shape": "train_4k",
              "chips": 8, "steps": 100}
    n = canonical_job_name(fields)
    back = job_fields_of(n)
    assert back["app"] == "train"
    assert back["arch"] == "qwen2-0.5b"
    assert back["chips"] == "8"
    assert back["steps"] == "100"


def test_canonical_name_is_order_independent():
    a = canonical_job_name({"app": "blast", "srr": "SRR1", "mem": 4, "cpu": 2})
    b = canonical_job_name({"cpu": 2, "mem": 4, "srr": "SRR1", "app": "blast"})
    assert a == b   # identical requests -> identical names -> cacheable


def test_paper_example_name_shape():
    # the paper's /ndn/k8s/compute/mem=4&cpu=6&app=BLAST convention
    n = canonical_job_name({"app": "blast", "mem": 4, "cpu": 6})
    assert str(n) == "/lidc/compute/blast/cpu=6&mem=4"


def test_arch_refines_prefix():
    n = canonical_job_name({"app": "train", "arch": "qwen2-0.5b"})
    assert Name.parse(COMPUTE_PREFIX + "/train/qwen2-0.5b").is_prefix_of(n)


def test_parse_job_malformed():
    with pytest.raises(ValueError):
        parse_job("novalue")
    with pytest.raises(ValueError):
        parse_job("a=1&a=2")


def test_encode_parse_property():
    pytest.importorskip("hypothesis", reason="property sweep needs hypothesis")
    from hypothesis import given, strategies as st

    field_keys = st.text(alphabet="abcdefghijklmnop_", min_size=1, max_size=8)
    field_vals = st.one_of(st.integers(0, 10 ** 9),
                           st.text(alphabet="abcXYZ0123-._", min_size=1,
                                   max_size=12))

    @given(st.dictionaries(field_keys, field_vals, min_size=1, max_size=6))
    def check(fields):
        enc = encode_job(fields)
        back = parse_job(enc)
        assert back == {k: str(v) for k, v in fields.items()}

    check()


def test_str_and_hash_are_cached_and_stable():
    n = Name.parse("/lidc/data/obj")
    s1, s2 = str(n), str(n)
    assert s1 is s2                       # computed once, cached
    assert hash(n) == hash(Name(("lidc", "data", "obj")))
    # cache fields never leak into equality
    m = Name(("lidc", "data", "obj"))
    str(n)                                # n cached, m not
    assert n == m and len({n, m}) == 1


def test_append_builds_from_components_directly():
    n = Name.parse("/a/b")
    assert n.append("seg=0").components == ("a", "b", "seg=0")
    assert n.append("c/d", "e").components == ("a", "b", "c", "d", "e")
    assert n.append("").components == ("a", "b")     # empties are dropped
    assert n.append(7).components == ("a", "b", "7")  # non-str coerced
    # appending never mutates the receiver (names are immutable)
    assert n.components == ("a", "b")


def test_prefix_property():
    pytest.importorskip("hypothesis", reason="property sweep needs hypothesis")
    from hypothesis import given, strategies as st

    @given(st.lists(st.sampled_from("abcdef"), min_size=1, max_size=6),
           st.lists(st.sampled_from("abcdef"), min_size=1, max_size=6))
    def check(a, b):
        na, nb = Name(tuple(a)), Name(tuple(b))
        if na.is_prefix_of(nb):
            assert list(nb.components[:len(na)]) == list(na.components)

    check()
