"""Multi-device behaviour (8 placeholder host devices via subprocess):
sharding rules, compressed cross-pod psum, expert-parallel MoE equivalence,
and one real dry-run cell.  Subprocesses are required because
xla_force_host_platform_device_count must be set before jax initializes.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

OLD_JAX = not hasattr(jax, "shard_map")   # jax<0.5: experimental shard_map


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_pspecs_rules_and_divisibility():
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import get_config
        from repro.models.model import bundle_for
        from repro.models.sharding import param_pspecs, set_rules
        from repro.launch.mesh import rules_for

        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_config("qwen3-1.7b")
        set_rules(rules_for(cfg, model_axis=4))
        bundle = bundle_for(cfg)
        shapes = jax.eval_shape(lambda k: bundle.init(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        with mesh:
            specs = param_pspecs(shapes)
        wq = specs["blocks"]["attn"]["wq"]
        assert wq == P(None, None, "model"), wq      # stacked leading dim
        emb = specs["embed"]["table"]
        assert emb == P("model", None), emb          # vocab over model
        norm = specs["final_norm"]["w"]
        assert norm == P(None), norm
        # n_heads*hd = 16*128 = 2048 divisible by 4 ok; norm replicated ok
        print("PSPECS_OK")
    """)
    assert "PSPECS_OK" in out


def test_compressed_psum_matches_plain_psum():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import compressed_psum_pod

        from repro.compat import make_mesh, shard_map
        mesh = make_mesh((4, 2), ("pod", "data"))
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(4, 64)).astype(np.float32))

        def plain(x):
            return jax.lax.psum(x, "pod")

        def compressed(x):
            return compressed_psum_pod(x, "pod")

        sm = lambda f: jax.jit(shard_map(
            f, mesh=mesh, in_specs=P("pod", None), out_specs=P("pod", None)))
        a = sm(plain)(x)
        b = sm(compressed)(x)
        err = float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
        assert err < 0.05, err      # int8 quantization error bound
        print("COMPRESS_OK", err)
    """)
    assert "COMPRESS_OK" in out


def test_moe_expert_parallel_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import smoke_of
        from repro.models import moe as MoE
        from repro.models.sharding import set_rules
        from repro.launch.mesh import rules_for

        cfg = smoke_of("qwen3-moe-30b-a3b")   # 8 experts
        cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        p = MoE.init_moe(cfg, key, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))

        # single-shard reference (no mesh)
        set_rules({})
        y_ref, aux_ref = MoE.moe_block(cfg, p, x)

        # expert-parallel over a 4-way model axis
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        set_rules(rules_for(cfg, model_axis=4, force_tp=True))
        with mesh:
            y_ep, aux_ep = jax.jit(
                lambda p, x: MoE.moe_block(cfg, p, x))(p, x)
        err = float(jnp.max(jnp.abs(y_ref - y_ep)))
        assert err < 1e-4, err
        aerr = abs(float(aux_ref) - float(aux_ep))
        assert aerr < 1e-5, aerr   # load-balance aux agrees across EP
        print("MOE_EP_OK", err, aerr)
    """)
    assert "MOE_EP_OK" in out


def test_grad_shardings_lower_and_compile():
    """A miniature version of the dry-run on 8 devices (fast)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import smoke_of, ShapeConfig
        from repro.models.model import bundle_for, input_specs
        from repro.models.sharding import param_pspecs, set_rules
        from repro.launch.mesh import rules_for
        from repro.optim import AdamW, constant
        from repro.train.step import make_train_step, train_state_shape

        cfg = smoke_of("qwen3-1.7b")
        shape = ShapeConfig("t", "train", 64, 8)
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        set_rules(rules_for(cfg, model_axis=4))
        opt = AdamW(lr=constant(1e-4))
        with mesh:
            st = train_state_shape(cfg, opt)
            sspec = param_pspecs(st)
            ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
            bspec = {"tokens": NamedSharding(mesh, P("data", None)),
                     "labels": NamedSharding(mesh, P("data", None))}
            step = make_train_step(cfg, opt, remat="dots")
            jf = jax.jit(step, in_shardings=(ns(sspec), bspec),
                         out_shardings=(ns(sspec), None),
                         donate_argnums=(0,))
            specs = input_specs(cfg, shape)
            compiled = jf.lower(st, specs).compile()
            assert compiled.cost_analysis() is not None
        print("MINI_DRYRUN_OK")
    """)
    assert "MINI_DRYRUN_OK" in out


@pytest.mark.xfail(OLD_JAX, strict=False,
                   reason="jax<0.5 rejects sharding constraints that mention "
                          "a manual axis inside a partial-auto shard_map")
def test_multipod_compressed_train_step_lowers():
    """Cross-pod int8 gradient compression inside the jitted train step."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import smoke_of, ShapeConfig
        from repro.models.sharding import param_pspecs, set_rules
        from repro.launch.mesh import rules_for
        from repro.optim import AdamW, constant
        from repro.train.step import make_train_step, train_state_shape

        cfg = smoke_of("qwen2-0.5b")
        shape = ShapeConfig("t", "train", 32, 8)
        from repro.compat import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        set_rules(rules_for(cfg, model_axis=2))
        opt = AdamW(lr=constant(1e-4))
        with mesh:
            st = train_state_shape(cfg, opt)
            sspec = param_pspecs(st)
            ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
            bspec = {"tokens": NamedSharding(mesh, P(("pod", "data"), None)),
                     "labels": NamedSharding(mesh, P(("pod", "data"), None))}
            step = make_train_step(cfg, opt, compress_pods=True, mesh=mesh)
            from repro.models.model import input_specs
            compiled = jax.jit(step, in_shardings=(ns(sspec), bspec),
                               out_shardings=(ns(sspec), None)
                               ).lower(st, input_specs(cfg, shape)).compile()
            hlo = compiled.as_text()
            assert "all-to-all" in hlo or "all-gather" in hlo
            assert "s8[" in hlo, "int8 wire format missing from HLO"
        print("COMPRESSED_STEP_OK")
    """)
    assert "COMPRESSED_STEP_OK" in out
