"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gating import moe_gating
from repro.kernels.ssd_scan import ssd_state_scan

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,H,K,hd,causal", [
    (2, 128, 128, 4, 2, 64, True),
    (1, 256, 256, 8, 8, 128, True),
    (2, 128, 256, 4, 1, 32, True),       # decode-style suffix queries
    (1, 128, 128, 2, 2, 80, False),      # non-128-aligned head dim
    (1, 64, 64, 6, 3, 16, True),
])
def test_flash_attention_shapes(B, Sq, Sk, H, K, hd, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, K, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64), dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol,
                               rtol=atol)


def test_chunked_attention_matches_ref():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 512, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 512, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 512, 2, 32), jnp.float32)
    out = ref.attention_chunked(q, k, v, causal=True, chunk_q=128)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Smax,H,K,hd,length,bk", [
    (2, 512, 8, 2, 64, 300, 128),
    (1, 1024, 4, 4, 128, 1024, 256),
    (2, 256, 4, 1, 32, 7, 64),           # nearly-empty cache
    (3, 384, 6, 2, 48, 200, 128),
])
def test_flash_decode(B, Smax, H, K, hd, length, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (B, Smax, K, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (B, Smax, K, hd), jnp.float32)
    out = flash_decode(q, ck, cv, jnp.asarray(length), block_k=bk,
                       interpret=True)
    want = ref.decode_attention_ref(q, ck, cv, jnp.asarray(length))
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_decode_ref_per_slot_lengths():
    ks = jax.random.split(KEY, 3)
    B, Smax, H, K, hd = 3, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (B, Smax, K, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (B, Smax, K, hd), jnp.float32)
    lengths = jnp.asarray([4, 100, 128])
    out = ref.decode_attention_ref(q, ck, cv, lengths)
    for b in range(B):
        one = ref.decode_attention_ref(q[b:b + 1], ck[b:b + 1], cv[b:b + 1],
                                       jnp.asarray(int(lengths[b])))
        np.testing.assert_allclose(out[b:b + 1], one, atol=1e-6)


# ---------------------------------------------------------------------------
# ssd state scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,C,H,P,N", [
    (2, 8, 4, 16, 16), (1, 16, 2, 32, 64), (3, 4, 1, 8, 8),
])
def test_ssd_state_scan(B, C, H, P, N):
    ks = jax.random.split(KEY, 2)
    xs = jax.random.normal(ks[0], (B, C, H, P, N), jnp.float32)
    a = jax.random.uniform(ks[1], (B, C, H), minval=0.3, maxval=0.99)
    prefix, fin = ssd_state_scan(xs, a, interpret=True)
    pref2, fin2 = ref.ssd_state_scan_ref(xs, a)
    np.testing.assert_allclose(prefix, pref2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(fin, fin2, atol=1e-5, rtol=1e-5)


def test_ssd_state_scan_init_state():
    ks = jax.random.split(KEY, 3)
    xs = jax.random.normal(ks[0], (1, 4, 2, 8, 8), jnp.float32)
    a = jax.random.uniform(ks[1], (1, 4, 2), minval=0.5, maxval=0.9)
    s0 = jax.random.normal(ks[2], (1, 2, 8, 8), jnp.float32)
    prefix, fin = ssd_state_scan(xs, a, s0, interpret=True)
    pref2, fin2 = ref.ssd_state_scan_ref(xs, a, s0)
    np.testing.assert_allclose(prefix, pref2, atol=1e-5)
    np.testing.assert_allclose(fin, fin2, atol=1e-5)


# ---------------------------------------------------------------------------
# moe gating
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,E,k,bt", [
    (512, 128, 8, 128), (256, 8, 2, 64), (1024, 64, 4, 256), (64, 16, 1, 64),
])
def test_moe_gating(T, E, k, bt):
    logits = jax.random.normal(KEY, (T, E), jnp.float32)
    w, ids = moe_gating(logits, k, block_t=bt, interpret=True)
    w2, ids2 = ref.moe_gating_ref(logits, k)
    assert bool(jnp.all(ids == ids2))
    np.testing.assert_allclose(w, w2, atol=1e-6)


def test_moe_gating_property():
    pytest.importorskip("hypothesis", reason="property sweep needs hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 4), st.integers(2, 6), st.integers(1, 3))
    def check(bt_pow, e_pow, k):
        T, E = 2 ** (bt_pow + 4), 2 ** e_pow
        k = min(k, E)
        logits = jax.random.normal(jax.random.PRNGKey(T + E + k), (T, E))
        w, ids = moe_gating(logits, k, block_t=T, interpret=True)
        # weights positive, sum to 1, ids unique per row
        assert bool(jnp.all(w > 0))
        np.testing.assert_allclose(jnp.sum(w, -1), jnp.ones(T), atol=1e-5)
        for row in np.asarray(ids):
            assert len(set(row.tolist())) == k

    check()
