"""Property tests: data-lake segmentation round-trips byte-identically.

Arbitrary object sizes — 0 B up to several segments, biased to the ±1
boundaries where off-by-ones live — must round-trip through the
manifest/seg publish→fetch path byte-identical, both via the direct API
and over the forwarding plane with signatures verifying.

Runs with a small ``segment_size`` so "several segments" stays fast;
the segmentation arithmetic is size-relative.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import numpy as np  # noqa: E402

from repro.core.forwarder import Consumer, Forwarder, Network  # noqa: E402
from repro.core.names import Name  # noqa: E402
from repro.core.packets import verify_data  # noqa: E402
from repro.datalake.lake import DataLake  # noqa: E402

SEG = 1024           # small segments so multi-segment objects stay cheap

# sizes hammer the segment boundaries: every k*SEG ± 1 up to 4 segments,
# plus arbitrary in-between sizes
boundary = st.sampled_from(
    [0, 1, SEG - 1, SEG, SEG + 1,
     2 * SEG - 1, 2 * SEG, 2 * SEG + 1,
     3 * SEG - 1, 3 * SEG, 3 * SEG + 1, 4 * SEG])
anywhere = st.integers(min_value=0, max_value=4 * SEG + 7)
sizes = st.one_of(boundary, anywhere)


def blob_of(size: int, seed: int) -> bytes:
    return np.random.default_rng(seed).integers(
        0, 256, size, dtype=np.uint8).tobytes()


@settings(max_examples=60, deadline=None)
@given(size=sizes, seed=st.integers(0, 2 ** 31 - 1))
def test_put_get_round_trips_byte_identical(size, seed):
    lake = DataLake(segment_size=SEG)
    blob = blob_of(size, seed)
    name = Name.parse(f"/lidc/data/prop/{size}")
    lake.put_bytes(name, blob)
    assert lake.get_bytes(name) == blob
    assert lake.has(name)
    # segmentation invariants: manifest iff the blob exceeds one segment
    man = lake.get_json(name.append("manifest"))
    if size <= SEG:
        assert man is None
    else:
        expected = -(-size // SEG)          # ceil
        assert man["segments"] == expected and man["size"] == size
        for i in range(expected):
            seg = lake.store.get(str(name.append(f"seg={i}")))
            assert seg is not None and 1 <= len(seg) <= SEG
        assert lake.store.get(
            str(name.append(f"seg={expected}"))) is None


@settings(max_examples=25, deadline=None)
@given(size=sizes, seed=st.integers(0, 2 ** 31 - 1))
def test_network_fetch_round_trips_with_valid_signature(size, seed):
    net = Network()
    node = Forwarder(net, "lake-node")
    lake = DataLake(segment_size=SEG)
    lake.attach(node)
    blob = blob_of(size, seed)
    name = Name.parse("/lidc/data/prop/net")
    lake.put_bytes(name, blob)

    box = Consumer(net, node).get(name)
    assert "data" in box, box
    d = box["data"]
    assert d.content == blob
    assert verify_data(d, lake.key)
    # a tampered packet must not verify
    import dataclasses
    forged = dataclasses.replace(d, content=d.content + b"x")
    assert not verify_data(forged, lake.key)


@settings(max_examples=20, deadline=None)
@given(size=st.integers(SEG + 1, 4 * SEG), seed=st.integers(0, 2 ** 31 - 1),
       missing=st.integers(0, 3))
def test_torn_objects_are_not_served(size, seed, missing):
    """Deleting any one segment makes the whole object unavailable."""
    lake = DataLake(segment_size=SEG)
    name = Name.parse("/lidc/data/prop/torn")
    lake.put_bytes(name, blob_of(size, seed))
    nseg = -(-size // SEG)
    lake.store.delete(str(name.append(f"seg={missing % nseg}")))
    assert lake.get_bytes(name) is None
