"""Bounded name caches: a 10k-task fan-out must not grow them unbounded.

The parse/intern caches sped up the hot path in the router work, but an
elastic map mints tens of thousands of distinct ``part=i`` names per
run — an unbounded cache is a slow memory leak.  These tests pin the
LRU discipline: hard capacity, eviction accounting, and recency (a hot
name survives churn that evicts cold ones).
"""

from repro.core.names import (Name, canonical_job_name,
                              configure_name_caches, name_cache_stats,
                              parse_job)

DEFAULTS = {"parse_capacity": 65536, "job_capacity": 16384}


def with_small_caches(parse=64, job=32):
    configure_name_caches(parse_capacity=parse, job_capacity=job)


def restore():
    configure_name_caches(**DEFAULTS)


def test_parse_cache_bounded_under_fanout_churn():
    with_small_caches()
    try:
        before = name_cache_stats()["parse_evictions"]
        for i in range(10_000):
            Name.parse(f"/lidc/compute/tm-map/part={i}&parts=10000")
        stats = name_cache_stats()
        assert stats["parse_size"] <= stats["parse_capacity"] == 64
        assert stats["parse_evictions"] > before
    finally:
        restore()


def test_job_cache_bounded_under_fanout_churn():
    with_small_caches()
    try:
        before = name_cache_stats()["job_evictions"]
        for i in range(10_000):
            parse_job(f"fn=wordcount&part={i}&parts=10000")
        stats = name_cache_stats()
        assert stats["job_size"] <= stats["job_capacity"] == 32
        assert stats["job_evictions"] > before
    finally:
        restore()


def test_lru_recency_keeps_hot_entry():
    """A name re-parsed between churn bursts stays cached (same object
    back), while the cold churn names are evicted around it."""
    with_small_caches(parse=16)
    try:
        hot = "/lidc/status/podA/jobhot"
        first = Name.parse(hot)
        for i in range(200):
            Name.parse(f"/lidc/data/churn/{i}")
            if i % 8 == 0:
                Name.parse(hot)             # touch: move to MRU
        assert Name.parse(hot) is first     # identity == cache hit
        stats = name_cache_stats()
        assert stats["parse_size"] <= 16
    finally:
        restore()


def test_configure_shrink_trims_immediately():
    with_small_caches(parse=128, job=128)
    try:
        for i in range(128):
            Name.parse(f"/lidc/data/trim/{i}")
            parse_job(f"k={i}")
        configure_name_caches(parse_capacity=8, job_capacity=8)
        stats = name_cache_stats()
        assert stats["parse_size"] <= 8
        assert stats["job_size"] <= 8
    finally:
        restore()


def test_canonical_name_identical_after_eviction():
    """Eviction is invisible to correctness: the canonical name built
    before and after a full cache wipe is byte-identical (exactly-once
    depends on this)."""
    fields = {"app": "tm-map", "fn": "wordcount", "part": 7, "parts": 100}
    a = str(canonical_job_name(fields))
    with_small_caches(parse=4, job=4)
    try:
        for i in range(100):
            Name.parse(f"/lidc/data/wipe/{i}")
            parse_job(f"w={i}")
        assert str(canonical_job_name(fields)) == a
    finally:
        restore()
