"""Adaptive strategy: probe, learn, shift away from degraded upstreams.

All scenarios run on the deterministic virtual clock, so "shifts within
N interests" is asserted exactly, not statistically.
"""

from repro.core.forwarder import Consumer, Forwarder, Nack, Network, link
from repro.core.jobs import JobSpec
from repro.core.matchmaker import MatchError, Matchmaker, ServiceEndpoint
from repro.core.names import Name
from repro.core.packets import Data
from repro.core.scheduler import CompletionModel
from repro.core.strategy import AdaptiveStrategy, CompletionTimeStrategy
import pytest


def _producer(node, prefix, value=b"v", fail_box=None):
    calls = {"n": 0}

    def handler(interest, publish, now):
        calls["n"] += 1
        if fail_box is not None and fail_box.get("fail"):
            return Nack(interest, "synthetic")
        if fail_box is not None and fail_box.get("silent"):
            return None    # accepted, never answers — the dark-cluster case
        return Data(name=interest.name, content=value, created_at=now,
                    freshness=10.0)

    node.attach_producer(Name.parse(prefix), handler)
    return calls


def _star4(strategy):
    """Hub + 4 producer leaves, all serving /svc, increasing cost order."""
    net = Network()
    hub = Forwarder(net, "hub", strategy=strategy)
    leaves = []
    for i in range(4):
        leaf = Forwarder(net, f"leaf{i}")
        hub_face, _ = link(net, hub, leaf, latency=0.001)
        leaves.append((leaf, hub_face))
        hub.register_route(Name.parse("/svc"), hub_face, cost=1.0 + i)
    return net, hub, leaves


def test_cold_prefix_parallel_probe():
    strat = AdaptiveStrategy(probe_fanout=2)
    net, hub, leaves = _star4(strat)
    calls = [_producer(leaf, "/svc") for leaf, _ in leaves]
    c = Consumer(net, hub)
    box = c.get(Name.parse("/svc/first"))
    assert box["data"].content == b"v"
    # the cold prefix was probed on the two cheapest upstreams at once
    assert strat.probes == 1
    assert calls[0]["n"] == 1 and calls[1]["n"] == 1
    assert calls[2]["n"] == 0 and calls[3]["n"] == 0


def test_adaptive_shifts_off_nacking_upstream_within_n_interests():
    strat = AdaptiveStrategy(probe_fanout=2, explore_every=10_000)
    net, hub, leaves = _star4(strat)
    fail0 = {"fail": False}
    calls = [_producer(leaves[0][0], "/svc", fail_box=fail0)]
    calls += [_producer(leaf, "/svc") for leaf, _ in leaves[1:]]
    c = Consumer(net, hub)
    # warm-up: leaf0 (cheapest, healthy) wins the traffic
    for i in range(10):
        assert "data" in c.get(Name.parse(f"/svc/warm{i}"))
    warm0 = calls[0]["n"]
    assert warm0 >= 9   # probe touched leaf1 once; everything else to leaf0
    # leaf0 starts NACKing every request
    fail0["fail"] = True
    shift_window = []
    for i in range(8):
        box = c.get(Name.parse(f"/svc/degraded{i}"))
        assert "data" in box      # failover inside each request keeps service up
        shift_window.append(calls[0]["n"])
    # within 3 interests the loss EWMA must push leaf0 out of the top slot
    # (no further first-choice traffic -> its call count stops growing)
    assert shift_window[3:] == [shift_window[3]] * 5
    assert calls[0]["n"] - warm0 <= 4
    # and the traffic went somewhere healthy
    assert sum(cl["n"] for cl in calls[1:]) >= 8


def test_adaptive_recovers_after_upstream_heals():
    strat = AdaptiveStrategy(probe_fanout=2, explore_every=4)
    net, hub, leaves = _star4(strat)
    fail0 = {"fail": False}
    calls0 = _producer(leaves[0][0], "/svc", fail_box=fail0)
    for leaf, _ in leaves[1:]:
        _producer(leaf, "/svc")
    c = Consumer(net, hub)
    for i in range(6):
        c.get(Name.parse(f"/svc/a{i}"))
    fail0["fail"] = True
    for i in range(6):
        c.get(Name.parse(f"/svc/b{i}"))
    fail0["fail"] = False
    before = calls0["n"]
    # exploration retries the cheap upstream; successes decay its loss EWMA
    # and it wins the ranking back
    for i in range(30):
        c.get(Name.parse(f"/svc/c{i}"))
    assert calls0["n"] > before


def test_timeout_feeds_loss_signal_for_silent_upstream():
    """A silent cluster never NACKs; retransmission + losing-the-race
    feedback must teach the strategy without any explicit failure signal."""
    strat = AdaptiveStrategy(probe_fanout=1, explore_every=10_000)
    net, hub, leaves = _star4(strat)
    silence0 = {"silent": False}
    calls = [_producer(leaves[0][0], "/svc", fail_box=silence0)]
    calls += [_producer(leaf, "/svc") for leaf, _ in leaves[1:]]
    c = Consumer(net, hub)
    for i in range(4):
        c.get(Name.parse(f"/svc/w{i}"))
    assert calls[0]["n"] == 4
    silence0["silent"] = True        # accepts interests, never answers
    for i in range(4):
        box = c.get(Name.parse(f"/svc/dark{i}"), retries=3, lifetime=0.25)
        assert "data" in box         # retransmission fails over mid-request
    # the strategy learned: the silent face carries loss, and only the
    # first degraded interest ever reached it
    hub_face0 = leaves[0][1]
    hop0 = hub.fib.nexthops(Name.parse("/svc"))[hub_face0.face_id]
    assert hop0.loss_ewma > 0.0
    assert calls[0]["n"] == 5        # exactly one wasted try, then it shifted
    assert sum(cl["n"] for cl in calls[1:]) >= 4


# ---------------------------------------------------------------------------
# strategy signals consumed by scheduler + matchmaker
# ---------------------------------------------------------------------------

def test_completion_strategy_penalizes_lossy_transport():
    model = CompletionModel()
    fields = {"app": "train", "arch": "a", "chips": 4, "steps": 10}
    # identical compute history on faces 1 and 2
    for face in (1, 2):
        model.observe(fields, face_id=face, duration=10.0)
    strat = CompletionTimeStrategy(model)
    # face 2's transport is flapping
    for _ in range(6):
        strat.feedback(Name.parse("/lidc/compute/train/a"), 2, False, 0.1, 0.0)
    assert model.transport_penalty(2) > model.transport_penalty(1) == 1.0
    p1 = model.predict(fields, face_id=1) * model.transport_penalty(1)
    p2 = model.predict(fields, face_id=2) * model.transport_penalty(2)
    assert p2 > p1


def test_matchmaker_queued_admission_and_backpressure():
    ep = ServiceEndpoint(service="svc", app="train", max_chips=8)
    spec = JobSpec(app="train", fields={"chips": 8})
    mm = Matchmaker(max_queue_depth=2)
    # chips busy (free=0) but the job fits total capacity -> queued admission
    got = mm.match(spec, [ep], free_chips=0, queue_depth=0, total_chips=8)
    assert got[0] is ep and got[1] == 8
    # queue full -> backpressure (gateway will NACK, strategies divert)
    with pytest.raises(MatchError):
        mm.match(spec, [ep], free_chips=0, queue_depth=2, total_chips=8)
    # default matchmaker (depth 0) keeps the old fail-fast behaviour
    with pytest.raises(MatchError):
        Matchmaker().match(spec, [ep], free_chips=0, total_chips=8)


def test_cluster_waitq_starts_jobs_as_chips_free(monkeypatch=None):
    from repro.core.cluster import ComputeCluster, ExecResult
    net = Network()
    cluster = ComputeCluster(net, "c0", chips=8, max_queue_depth=4)
    cluster.add_endpoint(ServiceEndpoint(
        service="svc", app="train", max_chips=8,
        executor=lambda job, cl: ExecResult(payload={"ok": 1}, duration=1.0)))
    j1 = cluster.submit(JobSpec(app="train", fields={"chips": 8}), now=0.0)
    j2 = cluster.submit(JobSpec(app="train", fields={"chips": 8}), now=0.0)
    assert j1.state.value == "Running" and j2.state.value == "Pending"
    net.run()
    assert j1.state.value == "Completed" and j2.state.value == "Completed"
    assert j2.started_at is not None and j2.started_at >= 1.0


def test_pending_slots_released_after_multicast_race():
    from repro.core.strategy import MulticastStrategy
    net, hub, leaves = _star4(MulticastStrategy(k=2))
    for leaf, _ in leaves:
        _producer(leaf, "/svc")
    c = Consumer(net, hub)
    for i in range(5):
        assert "data" in c.get(Name.parse(f"/svc/race{i}"))
    for hop in hub.fib.nexthops(Name.parse("/svc")).values():
        assert hop.pending == 0      # race losers release their slots
        assert hop.failures == 0     # ...without being penalized


def test_nack_outcome_not_double_counted_when_data_arrives():
    strat = AdaptiveStrategy(probe_fanout=1, explore_every=10_000)
    net, hub, leaves = _star4(strat)
    fail0 = {"fail": True}
    calls0 = _producer(leaves[0][0], "/svc", fail_box=fail0)
    for leaf, _ in leaves[1:]:
        _producer(leaf, "/svc")
    c = Consumer(net, hub)
    assert "data" in c.get(Name.parse("/svc/x"))
    hop0 = hub.fib.nexthops(Name.parse("/svc"))[leaves[0][1].face_id]
    assert calls0["n"] == 1
    assert hop0.failures == 1        # one NACK = exactly one failure
    assert hop0.pending == 0
