"""HLO parser: collective accounting with while-trip multiplication."""

from repro.roofline.analysis import HW, collective_bytes_from_hlo
from repro.roofline.hloparse import _shape_bytes, _split_def, analyze_hlo

SYNTH_HLO = """
HloModule synth

%body (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,128]{1,0} get-tuple-element(%p), index=1
  %ag = f32[64,256]{1,0} all-gather(%x), channel_id=1, dimensions={1}
  %dot = f32[64,64]{1,0} dot(%ag, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  %ar = f32[64,128]{1,0} all-reduce(%x), channel_id=2, to_apply=%add
  ROOT %t = (s32[], f32[64,128]{1,0}) tuple(%i, %ar)
}

%cond (p: (s32[], f32[64,128])) -> pred[] {
  %p = (s32[], f32[64,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %limit = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i, %limit), direction=LT
}

ENTRY %main (a: f32[64,128]) -> f32[64,128] {
  %a = f32[64,128]{1,0} parameter(0)
  %cp = f32[64,128]{1,0} collective-permute(%a), source_target_pairs={{0,1}}
  %init = (s32[], f32[64,128]{1,0}) tuple(%a)
  %w = (s32[], f32[64,128]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[64,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert _shape_bytes("pred[]") == 1


def test_split_def_tuple_types():
    parts = _split_def(
        "  %w = (s32[], f32[3,128,32]{2,1,0}) while(%t), condition=%c, "
        "body=%b")
    assert parts is not None
    name, type_str, op, args, attrs = parts
    assert op == "while" and "condition=%c" in attrs


def test_while_trip_multiplication():
    total, by_kind = collective_bytes_from_hlo(SYNTH_HLO)
    ag = 64 * 256 * 4          # inside while: x12
    ar = 64 * 128 * 4          # inside while: x12
    cp = 64 * 128 * 4          # entry: x1
    assert by_kind["all-gather"] == ag * 12
    assert by_kind["all-reduce"] == ar * 12
    assert by_kind["collective-permute"] == cp
    assert total == ag * 12 + ar * 12 + cp


def test_dot_flops_with_trips():
    stats = analyze_hlo(SYNTH_HLO)
    # dot: out (64,64), contract 256 -> 2*64*64*256 flops, x12 trips
    assert stats.flops == 2 * 64 * 64 * 256 * 12


def test_hw_constants_present():
    assert HW["peak_flops"] == 197e12
    assert HW["hbm_bw"] == 819e9
    assert HW["ici_bw"] == 50e9
