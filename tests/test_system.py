"""End-to-end behaviour of the LIDC system (the paper's workflow, Fig. 5)."""

import pytest

from repro.ckpt.checkpoint import latest_step
from repro.core.jobs import JobSpec
from repro.core.strategy import CompletionTimeStrategy
from repro.core.scheduler import CompletionModel
from repro.runtime.fleet import build_fleet, resilient_run


def small_fleet(n=2, **kw):
    return build_fleet(n_clusters=n, chips=8, archs=["lidc-demo"],
                       ckpt_every=5, **kw)


def test_full_job_workflow():
    sys_ = small_fleet()
    h = sys_.client.run_job({"app": "train", "arch": "lidc-demo",
                             "shape": "custom", "chips": 4, "steps": 8})
    assert h is not None and h.state == "Completed"
    assert h.result["final_loss"] is not None
    assert h.result["real_compute"] is True
    # the receipt carried the paper's protocol fields
    assert "status_name" in h.receipt and "result_name" in h.receipt


def test_identical_request_served_from_cache():
    sys_ = small_fleet()
    fields = {"app": "train", "arch": "lidc-demo", "shape": "custom",
              "chips": 4, "steps": 6}
    h1 = sys_.client.run_job(fields)
    jobs_before = sum(len(c.jobs) for c in sys_.overlay.clusters.values())
    h2 = sys_.client.run_job(fields)
    jobs_after = sum(len(c.jobs) for c in sys_.overlay.clusters.values())
    assert h1.state == h2.state == "Completed"
    assert jobs_after == jobs_before          # no new job was spawned
    assert h2.result is not None


def test_validation_rejects_bad_jobs():
    sys_ = small_fleet()
    # unknown arch
    h = sys_.client.submit({"app": "train", "arch": "not-a-model",
                            "chips": 4, "steps": 1})
    assert h is None or h.state != "Completed"
    # the paper's example: malformed SRR id
    h2 = sys_.client.submit({"app": "blast", "srr": "banana"})
    assert h2 is None
    # too many chips
    h3 = sys_.client.submit({"app": "train", "arch": "lidc-demo",
                             "chips": 4096, "steps": 1})
    assert h3 is None


def test_status_protocol_states():
    sys_ = small_fleet()
    h = sys_.client.run_job({"app": "blast", "srr": "SRR2931415",
                             "db": "human", "mem": 4, "cpu": 2})
    assert h.state == "Completed"
    states = {s["state"] for s in h.status_history}
    assert states <= {"Pending", "Running", "Completed", "Failed"}
    assert h.result["output_bytes"] > 0


@pytest.mark.slow
def test_failover_resumes_from_named_checkpoint():
    sys_ = small_fleet()
    fields = {"app": "train", "arch": "lidc-demo", "shape": "custom",
              "chips": 4, "steps": 20, "tag": "failover-test"}
    spec = JobSpec(app="train",
                   fields={k: v for k, v in fields.items() if k != "app"})
    run_name = f"train-{spec.signature()}"

    killed = {"done": False}
    orig = sys_.lake.put_json

    def hook(name, obj, **kw):
        r = orig(name, obj, **kw)
        if ("ckpt" in str(name) and "latest" in str(name)
                and not killed["done"] and obj.get("step", 0) >= 10):
            killed["done"] = True
            sys_.overlay.fail_cluster(
                next(iter(sys_.overlay.clusters)))
        return r

    sys_.lake.put_json = hook
    h, attempts = resilient_run(sys_, fields)
    assert killed["done"], "failure injection never triggered"
    assert h.state == "Completed"
    assert attempts >= 2
    assert h.result["resumed_from"] is not None
    assert latest_step(sys_.lake, run_name) == 20


def test_cluster_join_during_operation():
    from repro.runtime.fleet import standard_endpoints
    from repro.runtime.executors import memory_model
    sys_ = small_fleet(n=1)
    sys_.overlay.fail_cluster("pod0")
    fields = {"app": "train", "arch": "lidc-demo", "shape": "custom",
              "chips": 4, "steps": 4}
    h = sys_.client.submit(fields)
    assert h is None or h.state != "Completed"
    # a new cluster joins the overlay — no controller to update
    sys_.add_cluster("latecomer", chips=8,
                     endpoints=standard_endpoints(["lidc-demo"]),
                     memory_model=memory_model)
    h2 = sys_.client.run_job(fields)
    assert h2 is not None and h2.state == "Completed"
    assert h2.result["cluster"] == "latecomer"


def test_completion_time_strategy_learns():
    model = CompletionModel()
    sys_ = build_fleet(n_clusters=2, chips=8, archs=["lidc-demo"],
                       strategy=CompletionTimeStrategy(model))
    fields = {"app": "blast", "srr": "SRR2931415", "db": "human",
              "mem": 4, "cpu": 2}
    h = sys_.client.run_job(fields)
    assert h.state == "Completed"
    # feed the observation back (the Table-I learning loop)
    spec_fields = {"app": "blast", "srr": "SRR2931415", "db": "human",
                   "mem": "4", "cpu": "2"}
    model.observe(spec_fields, face_id=1, duration=h.result["run_time_s"])
    assert model.predict(spec_fields, face_id=1) is not None


def test_blast_table1_cpu_mem_insensitivity():
    """The paper's central Table-I observation: varying cpu/mem barely
    changes run time (it is I/O-bound)."""
    sys_ = small_fleet()
    times = []
    for cpu, mem in [(2, 4), (4, 4), (2, 6)]:
        h = sys_.client.run_job({"app": "blast", "srr": "SRR2931415",
                                 "db": "human", "mem": mem, "cpu": cpu})
        times.append(h.result["run_time_s"])
    spread = (max(times) - min(times)) / max(times)
    assert spread < 0.05     # <5% variation, like Table I
