"""Byte-budgeted Content Store: eviction accounting + index consistency.

The byte budget is what keeps one windowed bulk transfer from flushing
thousands of tiny cached compute results: bulk Data competes for bytes,
not LRU slots.  The churn property test pins the invariant the prefix
index must keep under any interleaving of insert / evict / evict_prefix.
"""

import pytest

from repro.core.names import Name
from repro.core.packets import Data, Interest
from repro.core.tables import ContentStore


def d(name: str, size: int = 1) -> Data:
    return Data(name=Name.parse(name), content=b"x" * size)


def match_name(cs: ContentStore, name: Name):
    """Exact-name cache probe."""
    return cs.match(Interest(name=name), now=0.0)


def assert_consistent(cs: ContentStore) -> None:
    """Store <-> prefix-index <-> byte-count coherence."""
    if cs._unindexed:
        cs._index_pending()     # indexing is lazy: materialize, then check
    for key in cs._store:
        for i in range(len(key) + 1):
            assert key in cs._prefix_index.get(key[:i], set()), \
                f"{key} missing from bucket {key[:i]}"
    for prefix, bucket in cs._prefix_index.items():
        assert bucket, f"empty bucket {prefix} left behind"
        for key in bucket:
            assert key in cs._store and key[:len(prefix)] == prefix
    assert cs.bytes_stored == sum(len(v.content) for v in cs._store.values())


def test_byte_budget_evicts_lru():
    cs = ContentStore(capacity=100, capacity_bytes=10)
    for i in range(5):
        cs.insert(d(f"/n/{i}", size=4))      # 20 B total -> only 2 fit
    assert len(cs) == 2 and cs.bytes_stored == 8
    assert match_name(cs, Name.parse("/n/4")) is not None
    assert match_name(cs, Name.parse("/n/0")) is None


def test_bytes_stored_tracks_replacement():
    cs = ContentStore(capacity_bytes=100)
    cs.insert(d("/a", size=40))
    cs.insert(d("/a", size=10))              # replace, don't double-count
    assert cs.bytes_stored == 10 and len(cs) == 1
    cs.evict_prefix(Name.parse("/a"))
    assert cs.bytes_stored == 0


def test_oversize_data_is_not_admitted():
    cs = ContentStore(capacity_bytes=64)
    for i in range(4):
        cs.insert(d(f"/small/{i}", size=8))
    cs.insert(d("/huge", size=1000))         # would flush everything: refuse
    assert len(cs) == 4 and cs.bytes_stored == 32
    assert match_name(cs, Name.parse("/huge")) is None


def test_oversize_replacement_evicts_the_stale_prior_entry():
    """Declining to cache a new oversize version must not leave the old
    smaller Data answering with outdated content."""
    cs = ContentStore(capacity_bytes=64)
    cs.insert(d("/x", size=8))
    cs.insert(d("/x", size=1000))            # refused — and /x invalidated
    assert match_name(cs, Name.parse("/x")) is None
    assert len(cs) == 0 and cs.bytes_stored == 0


def test_entry_count_budget_still_applies():
    cs = ContentStore(capacity=3, capacity_bytes=10 ** 9)
    for i in range(10):
        cs.insert(d(f"/n/{i}"))
    assert len(cs) == 3


def test_stats_exposes_bytes():
    cs = ContentStore(capacity_bytes=100)
    cs.insert(d("/a/b", size=7))
    s = cs.stats()
    assert s["bytes_stored"] == 7 and s["entries"] == 1


def test_mixed_sizes_dont_starve_small_entries():
    """One 32x-bigger object must not evict every small result."""
    cs = ContentStore(capacity=4096, capacity_bytes=100)
    for i in range(50):
        cs.insert(d(f"/result/{i}", size=1))
    cs.insert(d("/bulk/seg=0", size=60))
    kept = sum(1 for i in range(50)
               if match_name(cs, Name.parse(f"/result/{i}")) is not None)
    assert kept >= 40       # bytes were reclaimed, not slots


def test_property_prefix_index_consistent_under_churn():
    pytest.importorskip("hypothesis", reason="property sweep needs hypothesis")
    from hypothesis import given, settings, strategies as st

    comp = st.sampled_from("abc")
    name = st.lists(comp, min_size=1, max_size=3).map(
        lambda cs_: "/" + "/".join(cs_))
    op = st.one_of(
        st.tuples(st.just("insert"), name, st.integers(1, 9)),
        st.tuples(st.just("evict"), name, st.just(0)),
    )

    @settings(max_examples=80, deadline=None)
    @given(st.lists(op, min_size=1, max_size=60),
           st.integers(2, 8), st.integers(8, 64))
    def check(ops, cap, cap_bytes):
        cs = ContentStore(capacity=cap, capacity_bytes=cap_bytes)
        for kind, n, size in ops:
            if kind == "insert":
                cs.insert(d(n, size=size))
            else:
                cs.evict_prefix(Name.parse(n))
            assert len(cs) <= cap and cs.bytes_stored <= cap_bytes
        assert_consistent(cs)

    check()
