"""Pipeline parallelism: GPipe schedule == sequential model, exactly."""

import os
import subprocess
import sys

import jax
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

OLD_JAX = not hasattr(jax, "shard_map")   # jax<0.5: experimental shard_map


@pytest.mark.slow
@pytest.mark.xfail(OLD_JAX, strict=False,
                   reason="jax<0.5 experimental shard_map raises _SpecError "
                          "when transposing the pipeline stage function")
def test_pp_loss_and_grads_match_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs.base import smoke_of
from repro.models import bundle_for
from repro.models.sharding import set_rules
from repro.runtime.pipeline import make_pp_loss_fn, make_pp_mesh

set_rules({})
cfg = smoke_of("qwen3-1.7b")           # 2 layers
cfg = dataclasses.replace(cfg, n_layers=4, dtype="float32")
bundle = bundle_for(cfg)
key = jax.random.PRNGKey(0)
params = bundle.init(cfg, key)
B, S = 8, 16
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}

ref_loss, ref_grads = jax.value_and_grad(
    lambda p: bundle.loss_fn(cfg, p, batch))(params)

mesh = make_pp_mesh(4)
pp_loss_fn = make_pp_loss_fn(cfg, mesh, n_stages=4, n_micro=4)
with mesh:
    pp_loss, pp_grads = jax.jit(jax.value_and_grad(pp_loss_fn))(params, batch)

err = abs(float(ref_loss) - float(pp_loss))
assert err < 1e-4, (float(ref_loss), float(pp_loss))
for (ka, a), (kb, b) in zip(
        jax.tree_util.tree_leaves_with_path(ref_grads),
        jax.tree_util.tree_leaves_with_path(pp_grads)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3,
                               rtol=2e-2, err_msg=str(ka))
print("PP_OK", float(ref_loss), float(pp_loss))
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PP_OK" in out.stdout
