"""FIB longest-prefix-match, PIT aggregation, Content Store caching."""

import pytest

from repro.core.names import Name
from repro.core.packets import Data, Interest
from repro.core.tables import ContentStore, Fib, Pit


def test_fib_lpm_prefers_longest():
    fib = Fib()
    fib.register(Name.parse("/lidc/compute"), face_id=1)
    fib.register(Name.parse("/lidc/compute/train/qwen2-0.5b"), face_id=2)
    _, hops = fib.lookup(Name.parse("/lidc/compute/train/qwen2-0.5b/k=1"))
    assert hops[0].face_id == 2
    _, hops = fib.lookup(Name.parse("/lidc/compute/serve/x"))
    assert hops[0].face_id == 1


def test_fib_remove_face_purges_routes():
    fib = Fib()
    fib.register(Name.parse("/a"), 1)
    fib.register(Name.parse("/a"), 2)
    fib.remove_face(1)
    _, hops = fib.lookup(Name.parse("/a/b"))
    assert [h.face_id for h in hops] == [2]
    fib.remove_face(2)
    assert fib.lookup(Name.parse("/a/b")) == (None, [])


def test_pit_aggregation_and_dup_nonce():
    pit = Pit()
    i1 = Interest(name=Name.parse("/x/y"))
    e, new, dup = pit.insert(i1, in_face=1, now=0.0)
    assert new and not dup
    # same name, different consumer, different nonce -> aggregated
    i2 = Interest(name=Name.parse("/x/y"))
    e2, new2, dup2 = pit.insert(i2, in_face=2, now=0.0)
    assert not new2 and not dup2 and e2 is e
    assert e.in_faces == {1, 2}
    # duplicate nonce (loop) -> dropped
    _, _, dup3 = pit.insert(i1, in_face=3, now=0.0)
    assert dup3


def test_pit_expiry():
    pit = Pit()
    pit.insert(Interest(name=Name.parse("/x"), lifetime=1.0), 1, now=0.0)
    assert pit.expire(now=0.5) == []
    dead = pit.expire(now=1.5)
    assert len(dead) == 1 and len(pit) == 0


def test_pit_satisfy_prefix():
    pit = Pit()
    pit.insert(Interest(name=Name.parse("/x/y")), 1, now=0.0)
    got = pit.satisfy(Name.parse("/x/y/z"))   # data name extends interest
    assert len(got) == 1


def test_cs_exact_and_freshness():
    cs = ContentStore(capacity=10)
    d = Data(name=Name.parse("/a/b"), content=b"v", freshness=5.0,
             created_at=0.0)
    cs.insert(d)
    hit = cs.match(Interest(name=Name.parse("/a/b")), now=1.0)
    assert hit is not None
    stale = cs.match(Interest(name=Name.parse("/a/b"), must_be_fresh=True),
                     now=100.0)
    assert stale is None
    ok = cs.match(Interest(name=Name.parse("/a/b"), must_be_fresh=True),
                  now=2.0)
    assert ok is not None


def test_cs_lru_eviction():
    cs = ContentStore(capacity=3)
    for i in range(5):
        cs.insert(Data(name=Name.parse(f"/n/{i}"), content=b"x"))
    assert len(cs) == 3
    assert cs.match(Interest(name=Name.parse("/n/0")), 0.0) is None
    assert cs.match(Interest(name=Name.parse("/n/4")), 0.0) is not None


def test_cs_prefix_match_flag():
    cs = ContentStore()
    cs.insert(Data(name=Name.parse("/a/b/seg=0"), content=b"x"))
    assert cs.match(Interest(name=Name.parse("/a/b")), 0.0) is None
    assert cs.match(Interest(name=Name.parse("/a/b"), can_be_prefix=True),
                    0.0) is not None


def test_cs_capacity_invariant():
    pytest.importorskip("hypothesis", reason="property sweep needs hypothesis")
    from hypothesis import given, strategies as st

    @given(st.lists(st.integers(0, 30), min_size=1, max_size=60))
    def check(keys):
        cs = ContentStore(capacity=8)
        for k in keys:
            cs.insert(Data(name=Name.parse(f"/k/{k}"), content=b"v"))
        assert len(cs) <= 8

    check()


def test_cs_per_prefix_hit_rates():
    cs = ContentStore(capacity=16, prefix_stats_depth=2)
    cs.insert(Data(name=Name.parse("/a/hot/x"), content=b"v"))
    for _ in range(9):
        assert cs.match(Interest(name=Name.parse("/a/hot/x")), 0.0)
    assert cs.match(Interest(name=Name.parse("/a/hot/y")), 0.0) is None
    for _ in range(4):
        assert cs.match(Interest(name=Name.parse("/a/cold/z")), 0.0) is None
    assert cs.hit_rate_for(Name.parse("/a/hot/anything")) == 0.9
    assert cs.hit_rate_for(Name.parse("/a/cold/z")) == 0.0
    assert cs.hit_rate_for(Name.parse("/never/seen")) == 0.0
    rates = cs.prefix_hit_rates()
    assert rates == {"/a/hot": 0.9, "/a/cold": 0.0}
    # the scalar stays the blended rate (backward compat)
    assert cs.hit_rate == 9 / 14
    st = cs.stats()
    assert st["prefix_stats_entries"] == 2
    assert st["prefix_stats_evictions"] == 0


def test_cs_prefix_stats_bounded_under_churn():
    cs = ContentStore(capacity=4, prefix_stats_depth=2,
                      prefix_stats_capacity=8)
    for i in range(1000):
        cs.match(Interest(name=Name.parse(f"/p{i}/x")), 0.0)
    st = cs.stats()
    assert st["prefix_stats_entries"] <= 8
    assert st["prefix_stats_evictions"] == 1000 - 8
