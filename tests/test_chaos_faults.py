"""Gray-failure chaos plane: flapping, asymmetry, slowness, corruption.

Every fault class added by this PR keeps the two contracts the original
injector established:

* **replay determinism** — a fixed seed yields an identical injector
  trace and an identical ``(t, seq)`` network event trace, run twice on
  the calendar engine and once more on the heap engine;
* **invariants under fire** — workflows complete, the Content Store
  never serves corrupted bytes (the CS admission gate), flap storms
  leave no stale FIB state behind, and brownout sheds exactly the lowest
  priority classes.
"""

import itertools

import pytest

from repro.core.cluster import ComputeCluster, ExecResult
from repro.core.compute_plane import SchedulerConfig
from repro.core.forwarder import Consumer, Forwarder, Network, link
from repro.core.jobs import JobSpec
from repro.core.matchmaker import ServiceEndpoint
from repro.core.names import Name, canonical_job_name
from repro.core.overlay import LidcSystem, MeshTopology
from repro.core.packets import Data, Interest, sign_data, verify_trusted
from repro.core.strategy import AdaptiveStrategy
from repro.core.validation import ValidatorRegistry
from repro.workflow import FaultInjector, WorkflowEngine, WorkflowSpec
from repro.workflow.apps import build_workflow_fleet

DATASET = "/lidc/data/reads/chaos"


# ---------------------------------------------------------------------------
# replay determinism for every new fault class, on both engines
# ---------------------------------------------------------------------------

FAULT_KINDS = ["flap", "oneway", "slow", "corrupt", "duplicate", "reorder"]


def _chaos_scenario(kind, engine="calendar", seed=11):
    from repro.core import jobs
    jobs._job_seq = itertools.count(500)   # pin ids: payloads embed them
    system, log = build_workflow_fleet(
        4, chips=4, engine=engine,
        strategy=AdaptiveStrategy(probe_fanout=1, rotate_cold_probes=True))
    system.lake.put_bytes(Name.parse(DATASET), bytes(range(256)) * 4096)
    wf = (WorkflowSpec(f"chaos-{kind}")
          .stage("shard", "wf-shard", inputs=[DATASET], parts=4, tag=kind)
          .stage("align", "wf-align", inputs=["@shard"], fanout=4, tag=kind)
          .stage("merge", "wf-merge", inputs=["@align"], tag=kind)
          .compile())
    eng = WorkflowEngine(system.net, system.overlay.edge)
    inj = FaultInjector(system.net, seed=seed)
    faces = [f for pair in system.overlay.links.values() for f in pair]
    if kind == "flap":
        inj.flap_link(faces[:2], period=0.2, start=0.1, stop=1.3)
    elif kind == "oneway":
        inj.one_way_partition(system.overlay, "wfpod0", at=0.3, heal_at=2.0)
    elif kind == "slow":
        inj.slow_node(system.overlay.clusters["wfpod0"], 4.0,
                      start=0.0, stop=8.0)
    elif kind == "corrupt":
        inj.corrupt_link(faces, 0.15, start=0.0, stop=3.0)
    elif kind == "duplicate":
        inj.duplicate_link(faces, 0.25, start=0.0)
    elif kind == "reorder":
        inj.reorder_link(faces, 0.25, start=0.0)
    system.net.trace = []
    run = eng.start(wf)
    system.net.run()
    return run, log, inj, system.net.trace


@pytest.mark.parametrize("kind", FAULT_KINDS)
def test_fault_replay_is_deterministic_on_both_engines(kind):
    run_a, log_a, inj_a, tr_a = _chaos_scenario(kind)
    assert run_a.complete, (kind, run_a.stage_report())
    run_b, log_b, inj_b, tr_b = _chaos_scenario(kind)
    assert inj_a.trace == inj_b.trace
    assert tr_a == tr_b
    assert log_a.events == log_b.events
    assert run_a.trace == run_b.trace
    # the heap engine pops events in the same (time, seq) order
    run_h, log_h, inj_h, tr_h = _chaos_scenario(kind, engine="heap")
    assert inj_h.trace == inj_a.trace
    assert tr_h == tr_a
    assert log_h.events == log_a.events


def test_different_seed_changes_the_gray_trace():
    _, _, inj_a, tr_a = _chaos_scenario("corrupt", seed=11)
    _, _, inj_b, tr_b = _chaos_scenario("corrupt", seed=12)
    assert inj_a.trace == inj_b.trace      # arming schedule is seed-free
    assert tr_a != tr_b                    # per-packet decisions are not


# ---------------------------------------------------------------------------
# CS poisoning: corrupted Data must never enter (or be served from) a CS
# ---------------------------------------------------------------------------

def _signed_producer(node, prefix, *, key=b"origin-key", signer="origin"):
    calls = {"n": 0}

    def handler(interest, publish, now):
        calls["n"] += 1
        d = Data(name=interest.name, content=b"precious-bytes",
                 created_at=now, freshness=30.0)
        return sign_data(d, key, signer)

    node.attach_producer(Name.parse(prefix), handler)
    return calls


def test_corrupted_data_never_poisons_the_content_store():
    net = Network()
    hub = Forwarder(net, "hub")
    leaf = Forwarder(net, "leaf")
    hub_face, leaf_face = link(net, hub, leaf, latency=0.001)
    calls = _signed_producer(leaf, "/lake")
    hub.register_route(Name.parse("/lake"), hub_face)
    inj = FaultInjector(net, seed=3)
    # every Data leaf->hub is corrupted during the window
    inj.corrupt_link([leaf_face], 1.0, start=0.0, stop=0.5)
    c1 = Consumer(net, hub)
    box1 = c1.get(Name.parse("/lake/obj"), retries=0, lifetime=0.3)
    # the first consumer got garbage (it verifies end-to-end and would
    # retry in real flows) — and the hub's CS refused the poisoned copy
    assert verify_trusted(box1["data"]) is False
    assert hub.stats["cs_poison_rejected"] >= 1
    assert leaf_face.corruptions >= 1
    net.run(until=1.0)                     # corruption window over
    c2 = Consumer(net, hub)
    box2 = c2.get(Name.parse("/lake/obj"))
    # without the admission gate the CS would serve the cached garbage;
    # with it, the second fetch goes back upstream and verifies
    assert verify_trusted(box2["data"]) is True
    assert box2["data"].content == b"precious-bytes"
    assert leaf_face.tx_data == 2          # re-fetched upstream, not hub-CS
    # ...and the clean copy was admitted this time
    assert hub.cs.match(Interest(name=Name.parse("/lake/obj")),
                        now=net.now) is not None


def test_clean_data_still_caches_through_the_gate():
    net = Network()
    hub = Forwarder(net, "hub")
    leaf = Forwarder(net, "leaf")
    hub_face, _ = link(net, hub, leaf, latency=0.001)
    calls = _signed_producer(leaf, "/lake")
    hub.register_route(Name.parse("/lake"), hub_face)
    c = Consumer(net, hub)
    c.get(Name.parse("/lake/obj"))
    c.get(Name.parse("/lake/obj"))
    assert calls["n"] == 1                 # second hit served from CS
    assert hub.stats["cs_poison_rejected"] == 0


# ---------------------------------------------------------------------------
# flap storm: routing settles with no stale nexthops, tombstones hold
# ---------------------------------------------------------------------------

def _mesh_serve(mesh, origin, prefix):
    def handler(interest, publish, now):
        return Data(name=interest.name, content=b"v", created_at=now,
                    freshness=30.0)
    mesh.attach_producer(origin, Name.parse(prefix), handler)


def test_flap_storm_settles_to_bfs_oracle_and_tombstones_hold():
    net = Network()
    mesh = MeshTopology(net, 8, "random", seed=5)
    _mesh_serve(mesh, 0, "/svc/gone")
    _mesh_serve(mesh, 3, "/svc/keep")
    mesh.converge(timeout=20.0)
    assert mesh.is_converged()
    inj = FaultInjector(net, seed=9)
    # storm: three links square-wave through the withdrawal window
    edges = [k for k in mesh.faces if k[0] < k[1]][:3]
    for a, b in edges:
        inj.flap_link([mesh.faces[(a, b)], mesh.faces[(b, a)]],
                      period=0.3, start=0.0, stop=4.0)
    net.schedule(1.5, lambda: mesh.withdraw(0, Name.parse("/svc/gone")))
    net.run(until=4.5)
    mesh.converge(timeout=30.0)
    # the oracle check: reachability + min costs match global BFS, and the
    # withdrawn prefix resurrects nowhere (flap-replayed adverts are
    # sequence-gated by the tombstones)
    assert mesh.is_converged()
    for idx, node in enumerate(mesh.nodes):
        assert not node.fib.nexthops(Name.parse("/svc/gone")), node.name
    assert any(kind == "flap-down" for _, kind, _ in inj.trace)
    assert inj.trace[-1][1] == "flap-end"


# ---------------------------------------------------------------------------
# slow node: dilated execution, optimistic ETA, organic recovery
# ---------------------------------------------------------------------------

def _sim_cluster(net, *, chips=4, config=None, log=None):
    log = log if log is not None else []
    cluster = ComputeCluster(net, "c0", chips=chips, max_queue_depth=8,
                             scheduler_config=config)

    def executor(job, cl):
        log.append((job.spec.fields.get("u"), cl.name))
        return ExecResult(payload={"ok": 1},
                          duration=float(job.spec.fields.get("d", 1)))

    cluster.add_endpoint(ServiceEndpoint(
        service="sim.lidck8s.svc.cluster.local", app="sim",
        max_chips=1 << 20, executor=executor))
    return cluster, log


def test_slow_node_stretches_execution_but_not_the_quote():
    net = Network()
    cluster, log = _sim_cluster(net)
    inj = FaultInjector(net, seed=1)
    inj.slow_node(cluster, 3.0, start=0.0, stop=10.0)
    net.run(until=0.1)
    job = cluster.submit(JobSpec(app="sim", fields={"chips": 4, "d": 2.0,
                                                    "u": "slowed"}),
                         now=net.now)
    # the gray signature: the scheduler's release estimate stays nominal
    rec = cluster.scheduler._running[job.job_id]
    assert rec.expected_release == pytest.approx(net.now + 2.0)
    net.run()
    assert job.state.value == "Completed"
    assert job.finished_at == pytest.approx(0.1 + 3.0 * 2.0)   # dilated
    # healed: the next job runs at nominal speed again
    net.run(until=10.5)
    j2 = cluster.submit(JobSpec(app="sim", fields={"chips": 4, "d": 2.0,
                                                   "u": "healed"}),
                        now=net.now)
    net.run()
    assert j2.finished_at - j2.started_at == pytest.approx(2.0)
    assert [u for u, _ in log] == ["slowed", "healed"]


# ---------------------------------------------------------------------------
# brownout: shed lowest class first, quote growing ETAs
# ---------------------------------------------------------------------------

def _brownout_system(threshold=2):
    sys_ = LidcSystem()
    cfg = SchedulerConfig(brownout_queue_depth=threshold)
    cluster = ComputeCluster(sys_.net, "pod0", chips=4, lake=sys_.lake,
                             max_queue_depth=16, scheduler_config=cfg)

    def executor(job, cl):
        return ExecResult(payload={"ok": 1},
                          duration=float(job.spec.fields.get("d", 1)))

    cluster.add_endpoint(ServiceEndpoint(
        service="sim.lidck8s.svc.cluster.local", app="sim",
        max_chips=1 << 20, executor=executor))
    reg = ValidatorRegistry()
    reg.register("sim", lambda fields, caps: None)
    sys_.overlay.add_cluster(cluster, validators=reg)
    sys_.net.run(until=0.2)
    return sys_, cluster


def _express(sys_, t, fields, outcomes, uid):
    def submit():
        sys_.client.consumer.express(
            Interest(name=canonical_job_name(fields), lifetime=2.0,
                     must_be_fresh=True),
            on_data=lambda d: outcomes.__setitem__(uid, ("receipt", d)),
            on_fail=lambda r: outcomes.__setitem__(uid, ("fail", r)),
            retries=0)
    sys_.net.schedule(max(0.0, t - sys_.net.now), submit)


def test_brownout_sheds_lowest_class_and_admits_higher():
    sys_, cluster = _brownout_system(threshold=2)
    out = {}
    # occupy the chips, then queue two background jobs -> depth 2 = level 1
    _express(sys_, 0.30, {"app": "sim", "chips": 4, "d": 60, "u": "hog"},
             out, "hog")
    _express(sys_, 0.40, {"app": "sim", "chips": 4, "d": 1, "u": "q1"},
             out, "q1")
    _express(sys_, 0.50, {"app": "sim", "chips": 4, "d": 1, "u": "q2"},
             out, "q2")
    # under level-1 brownout a background arrival is shed outright...
    _express(sys_, 0.60, {"app": "sim", "chips": 4, "d": 1, "u": "shed"},
             out, "shed")
    # ...while a higher class is still admitted to the queue
    _express(sys_, 0.70, {"app": "sim", "chips": 4, "d": 1, "prio": 5,
                          "u": "vip"}, out, "vip")
    sys_.net.run(until=2.0)
    assert out["hog"][0] == "receipt"
    assert out["q1"][0] == "receipt" and out["q2"][0] == "receipt"
    assert out["shed"][0] == "fail"
    assert out["vip"][0] == "receipt"
    gw = sys_.overlay.gateways["pod0"]
    assert gw.brownouts == 1
    shed_nack = next(n for n in sys_.client.consumer.nacks
                     if "brownout" in n.reason)
    assert shed_nack.info is not None
    level = cluster.scheduler.brownout_level()
    assert level >= 1
    # the quoted ETA is stretched by the brownout level (busy receipts
    # quote scheduler.eta * (1 + growth * level))
    base_eta = cluster.scheduler.eta(
        JobSpec(app="sim", fields={"chips": 4, "d": 1}))
    growth = cluster.scheduler.cfg.brownout_eta_growth
    assert shed_nack.info["eta"] == pytest.approx(
        round(base_eta * (1 + growth * level), 6), rel=0.5)


def test_brownout_deepens_to_higher_classes_with_queue_depth():
    sys_, cluster = _brownout_system(threshold=1)
    out = {}
    _express(sys_, 0.30, {"app": "sim", "chips": 4, "d": 60, "u": "hog"},
             out, "hog")
    # one queued background + one queued prio-3 -> depth 2, threshold 1
    # -> level 2: both classes {0, 3} are shed for new arrivals
    _express(sys_, 0.40, {"app": "sim", "chips": 4, "d": 1, "u": "q0"},
             out, "q0")
    _express(sys_, 0.45, {"app": "sim", "chips": 4, "d": 1, "prio": 3,
                          "u": "q3"}, out, "q3")
    _express(sys_, 0.60, {"app": "sim", "chips": 4, "d": 1, "prio": 3,
                          "u": "shed3"}, out, "shed3")
    _express(sys_, 0.70, {"app": "sim", "chips": 4, "d": 1, "prio": 9,
                          "u": "vip"}, out, "vip")
    sys_.net.run(until=2.0)
    assert out["shed3"][0] == "fail"
    assert out["vip"][0] == "receipt"
    assert sys_.overlay.gateways["pod0"].brownouts == 1


def test_brownout_disabled_by_default_preserves_legacy_path():
    sys_, cluster = _brownout_system(threshold=2)
    assert SchedulerConfig().brownout_enabled is False
    # queue admission without brownout config never sheds
    sys2 = LidcSystem()
    cl2 = ComputeCluster(sys2.net, "pod0", chips=4, lake=sys2.lake,
                         max_queue_depth=16)
    cl2.add_endpoint(ServiceEndpoint(
        service="sim.lidck8s.svc.cluster.local", app="sim",
        max_chips=1 << 20,
        executor=lambda job, cl: ExecResult(payload={}, duration=1.0)))
    reg = ValidatorRegistry()
    reg.register("sim", lambda fields, caps: None)
    sys2.overlay.add_cluster(cl2, validators=reg)
    sys2.net.run(until=0.2)
    out = {}
    for i, t in enumerate((0.3, 0.4, 0.5, 0.6, 0.7)):
        _express(sys2, t, {"app": "sim", "chips": 4, "d": 60, "u": f"j{i}"},
                 out, f"j{i}")
    sys2.net.run(until=2.0)
    assert all(v[0] == "receipt" for v in out.values())
    assert sys2.overlay.gateways["pod0"].brownouts == 0


# ---------------------------------------------------------------------------
# soft-state repair: adverts lost in-flight must heal without re-flooding
# ---------------------------------------------------------------------------


def _announce(mesh, origin, prefix):
    mesh.attach_producer(origin, Name.parse(prefix),
                         lambda interest, publish, now: Data(
                             name=interest.name, content=b"v",
                             created_at=now, freshness=30.0))


def test_keepalive_digest_repairs_an_advert_eaten_by_a_lossy_link():
    """An advertisement dropped on an *up* face (gray loss, no carrier
    event, no hello silence) leaves the receiver permanently routeless
    under pure keepalive refresh — keepalives extend soft state but can't
    resurrect a route that never arrived.  The keepalive count digest
    must detect the hole and trigger an epoch resync within one refresh
    interval."""
    net = Network()
    mesh = MeshTopology(net, 2, "ring")
    _announce(mesh, 0, "/svc/early")
    assert mesh.converge(timeout=30)
    inj = FaultInjector(net, seed=3)
    t0 = net.now
    # total loss window around the new announcement: the advert (and its
    # retries-by-flush, if any) dies on the wire, both faces stay up
    inj.lossy_link([mesh.faces[(0, 1)], mesh.faces[(1, 0)]], 1.0,
                   start=t0 + 0.01, stop=t0 + 0.5)
    net.schedule(0.05, lambda: _announce(mesh, 0, "/svc/late"))
    net.run(until=t0 + 0.6)
    # the blackout was shorter than any failure detector bound: node 1
    # never declared node 0 dead, so no death-resync fixes this
    assert not mesh.nodes[1].fib.nexthops(Name.parse("/svc/late"))
    assert all(nb.alive for nb in mesh.agents[1].neighbors.values())
    # one keepalive refresh cycle later the digest mismatch must have
    # forced a resync
    net.run(until=t0 + mesh.routing_cfg.refresh_interval + 3.0)
    assert mesh.nodes[1].fib.nexthops(Name.parse("/svc/late"))
    assert mesh.agents[1].stats["resyncs_requested"] >= 1


def test_adverts_are_deferred_not_eaten_while_a_face_flaps_down():
    """A flap window shorter than one heartbeat is invisible to the
    carrier check: sending into the down face would record the advert as
    delivered while the wire ate it.  The agent must hold the batch and
    drain it once the carrier is back."""
    net = Network()
    mesh = MeshTopology(net, 2, "ring")
    _announce(mesh, 0, "/svc/early")
    assert mesh.converge(timeout=30)
    inj = FaultInjector(net, seed=4)
    t0 = net.now
    # down windows of 0.05s, far below hello_interval (0.25s) and
    # dead_interval; the announcement's triggered flush lands inside one
    inj.flap_link([mesh.faces[(0, 1)], mesh.faces[(1, 0)]],
                  period=0.1, start=t0 + 0.01, stop=t0 + 0.41)
    net.schedule(0.02, lambda: _announce(mesh, 0, "/svc/late"))
    net.run(until=t0 + 2.0)
    assert mesh.agents[0].stats["sends_deferred"] >= 1
    assert mesh.nodes[1].fib.nexthops(Name.parse("/svc/late"))
    assert mesh.is_converged()
