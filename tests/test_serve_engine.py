"""ServeEngine edge cases: slots, EOS, max_new=0, KV checkpoint/restore."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import bundle_for
from repro.serve.engine import (SUPPORTED_FAMILIES, ServeEngine,
                                UnsupportedFamilyError)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def demo():
    cfg = get_config("lidc-demo")
    bundle = bundle_for(cfg)
    params = bundle.init(cfg, KEY)
    return cfg, params


def test_unsupported_family_raises_typed_error(demo):
    cfg, params = demo
    moe_cfg = dataclasses.replace(cfg, family="moe")
    with pytest.raises(UnsupportedFamilyError) as exc:
        ServeEngine(moe_cfg, params, max_batch=1, max_seq=32)
    assert exc.value.family == "moe"
    assert "moe" in str(exc.value)
    assert isinstance(exc.value, ValueError)     # typed but still a ValueError
    assert cfg.family in SUPPORTED_FAMILIES


def test_slot_exhaustion_with_nonempty_queue(demo):
    """More requests than slots: the queue drains as slots free, every
    request completes, and the batch never exceeds max_batch."""
    cfg, params = demo
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=64)
    rng = np.random.default_rng(1)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab, 5)), max_new=4)
            for _ in range(6)]
    assert len(eng.queue) == 6 and all(s is None for s in eng.slots)
    done = eng.run()
    assert len(done) == 6 and all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert not eng.queue and all(s is None for s in eng.slots)


def test_eos_mid_batch_frees_slot_for_queued_request(demo):
    """A request finishing on EOS mid-batch hands its slot to a queued
    request without idle decode steps."""
    cfg, params = demo
    prompt = [3, 1, 4, 1, 5]
    # learn what greedy decode emits so we can make token #2 the EOS
    probe = ServeEngine(cfg, params, max_batch=1, max_seq=64)
    r = probe.submit(prompt, max_new=6)
    probe.run()
    eos = r.out[1]

    eng = ServeEngine(cfg, params, max_batch=1, max_seq=64)
    r1 = eng.submit(prompt, max_new=10, eos=eos)
    r2 = eng.submit([7, 8, 9], max_new=3)
    done = eng.run()
    assert [d.rid for d in done] == [r1.rid, r2.rid]
    assert r1.out[-1] == eos and len(r1.out) == 2   # stopped at EOS
    assert len(r2.out) == 3
    # no wasted steps: r1 took 1 decode step, r2 took its prefill + 2
    assert eng.decode_steps == 3


def test_eos_on_prefill_token_frees_slot_immediately(demo):
    cfg, params = demo
    prompt = [11, 12, 13]
    probe = ServeEngine(cfg, params, max_batch=1, max_seq=64)
    first = probe.submit(prompt, max_new=4)
    probe.run()
    eos = first.out[0]                      # the prefill-emitted token

    eng = ServeEngine(cfg, params, max_batch=1, max_seq=64)
    r = eng.submit(prompt, max_new=8, eos=eos)
    done = eng.run()
    assert done == [r] and r.out == [eos]
    assert eng.decode_steps == 0            # never entered the decode loop


def test_max_new_zero_finishes_without_slot(demo):
    cfg, params = demo
    eng = ServeEngine(cfg, params, max_batch=1, max_seq=32)
    r = eng.submit([1, 2, 3], max_new=0)
    assert r.done and r.out == [] and not eng.queue
    assert eng.run() == []
    assert eng.tokens_out == 0


def test_max_new_one_emits_exactly_one_token(demo):
    cfg, params = demo
    eng = ServeEngine(cfg, params, max_batch=1, max_seq=32)
    r = eng.submit([1, 2, 3], max_new=1)
    done = eng.run()
    assert done == [r] and len(r.out) == 1
    assert eng.decode_steps == 0


def test_priority_orders_admission(demo):
    cfg, params = demo
    eng = ServeEngine(cfg, params, max_batch=1, max_seq=32)
    lo = eng.submit([1, 2], max_new=2, priority=0)
    hi = eng.submit([3, 4], max_new=2, priority=5)
    done = eng.run()
    assert [d.rid for d in done] == [hi.rid, lo.rid]


def test_greedy_decode_survives_kv_checkpoint_restore(demo):
    """Checkpoint a mid-decode request, restore into a *fresh* engine,
    finish there: the token stream equals uninterrupted greedy decode."""
    cfg, params = demo
    prompt = [2, 7, 1, 8, 2, 8]
    max_new = 10

    solo = ServeEngine(cfg, params, max_batch=1, max_seq=64)
    want = solo.submit(prompt, max_new=max_new)
    solo.run()

    a = ServeEngine(cfg, params, max_batch=2, max_seq=64)
    r = a.submit(prompt, max_new=max_new)
    a._admit()
    for _ in range(3):                       # partway through decode
        a.step()
    assert 0 < len(r.out) < max_new
    state = a.kv_checkpoint(r)

    b = ServeEngine(cfg, params, max_batch=2, max_seq=64)
    restored = b.restore(state)
    assert restored.out == r.out             # picks up exactly where a was
    b.run()
    assert restored.done
    assert restored.out == want.out          # bit-identical to unbroken


def test_restore_rejects_when_full(demo):
    cfg, params = demo
    a = ServeEngine(cfg, params, max_batch=1, max_seq=64)
    r = a.submit([1, 2, 3], max_new=8)
    a._admit()
    a.step()
    state = a.kv_checkpoint(r)
    b = ServeEngine(cfg, params, max_batch=1, max_seq=64)
    b.submit([4, 5, 6], max_new=8)
    b._admit()                               # the only slot is now taken
    with pytest.raises(RuntimeError, match="no free slot"):
        b.restore(state)
