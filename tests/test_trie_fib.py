"""Trie FIB ≡ linear-scan oracle, hashed PIT behaviour, indexed CS behaviour.

The deterministic randomized equivalence test always runs; the
hypothesis property test adds minimized counterexamples where the
dependency is installed (CI).
"""

import itertools
import random

import pytest

from repro.core.names import Name
from repro.core.packets import Data, Interest
from repro.core.tables import ContentStore, Fib, LinearFib, Pit

COMPONENTS = ["a", "b", "c", "d", "lidc", "compute", "train", "serve",
              "q1", "q2", "x"]


def _rand_name(rng, max_len=6):
    return Name(tuple(rng.choice(COMPONENTS)
                      for _ in range(rng.randint(1, max_len))))


def _mirror_ops(rng, n_ops):
    """Apply one random op stream to both FIB implementations."""
    trie, oracle = Fib(), LinearFib()
    for _ in range(n_ops):
        roll = rng.random()
        prefix = _rand_name(rng, max_len=5)
        face = rng.randint(1, 6)
        if roll < 0.6:
            cost = rng.choice([1.0, 2.0, 3.0])
            trie.register(prefix, face, cost)
            oracle.register(prefix, face, cost)
        elif roll < 0.8:
            fid = face if rng.random() < 0.5 else None
            trie.unregister(prefix, fid)
            oracle.unregister(prefix, fid)
        else:
            trie.remove_face(face)
            oracle.remove_face(face)
    return trie, oracle

def _assert_equivalent(trie, oracle, rng, n_queries=40):
    assert len(trie) == len(oracle)
    assert sorted(map(str, trie.prefixes())) == sorted(map(str, oracle.prefixes()))
    for _ in range(n_queries):
        q = _rand_name(rng, max_len=7)
        m1, h1 = trie.lookup(q)
        m2, h2 = oracle.lookup(q)
        assert (m1 is None) == (m2 is None), str(q)
        if m1 is not None:
            assert m1.components == m2.components, str(q)
            assert ([(h.face_id, h.cost) for h in h1]
                    == [(h.face_id, h.cost) for h in h2])


def test_trie_equals_linear_oracle_randomized():
    for trial in range(150):
        rng = random.Random(trial)
        trie, oracle = _mirror_ops(rng, rng.randint(1, 80))
        _assert_equivalent(trie, oracle, rng)


def test_trie_equals_linear_oracle_property():
    pytest.importorskip("hypothesis", reason="property sweep needs hypothesis")
    from hypothesis import given, settings, strategies as st

    comp = st.sampled_from(COMPONENTS)
    name = st.lists(comp, min_size=1, max_size=5).map(tuple).map(Name)
    op = st.one_of(
        st.tuples(st.just("reg"), name, st.integers(1, 5),
                  st.sampled_from([1.0, 2.0, 3.0])),
        st.tuples(st.just("unreg"), name,
                  st.one_of(st.none(), st.integers(1, 5))),
        st.tuples(st.just("rmface"), st.integers(1, 5)),
    )

    @settings(max_examples=150, deadline=None)
    @given(st.lists(op, min_size=1, max_size=60),
           st.lists(st.lists(comp, min_size=1, max_size=7), min_size=1,
                    max_size=20))
    def check(ops, queries):
        trie, oracle = Fib(), LinearFib()
        for o in ops:
            if o[0] == "reg":
                trie.register(o[1], o[2], o[3])
                oracle.register(o[1], o[2], o[3])
            elif o[0] == "unreg":
                trie.unregister(o[1], o[2])
                oracle.unregister(o[1], o[2])
            else:
                trie.remove_face(o[1])
                oracle.remove_face(o[1])
        assert len(trie) == len(oracle)
        for q in queries:
            qn = Name(tuple(q))
            m1, h1 = trie.lookup(qn)
            m2, h2 = oracle.lookup(qn)
            assert (m1 is None) == (m2 is None)
            if m1 is not None:
                assert m1.components == m2.components
                assert ([(h.face_id, h.cost) for h in h1]
                        == [(h.face_id, h.cost) for h in h2])

    check()


def test_trie_edge_splits_and_merges():
    fib = Fib()
    fib.register(Name.parse("/a/b/c/d"), 1)
    # splitting the compressed /a/b/c/d edge
    fib.register(Name.parse("/a/b"), 2)
    m, h = fib.lookup(Name.parse("/a/b/c/d/e"))
    assert str(m) == "/a/b/c/d" and h[0].face_id == 1
    m, h = fib.lookup(Name.parse("/a/b/x"))
    assert str(m) == "/a/b" and h[0].face_id == 2
    # removing the inner prefix must re-merge without breaking the deep one
    fib.unregister(Name.parse("/a/b"))
    assert fib.lookup(Name.parse("/a/b/x")) == (None, [])
    m, _ = fib.lookup(Name.parse("/a/b/c/d"))
    assert str(m) == "/a/b/c/d"
    assert len(fib) == 1


def test_trie_remove_face_purges_only_that_face():
    fib = Fib()
    for i, p in enumerate(["/x", "/x/y", "/z"]):
        fib.register(Name.parse(p), 1)
        fib.register(Name.parse(p), 2, cost=2.0)
    fib.remove_face(1)
    for p in ["/x", "/x/y", "/z"]:
        hops = fib.nexthops(Name.parse(p))
        assert list(hops) == [2]
    fib.remove_face(2)
    assert len(fib) == 0
    assert fib.lookup(Name.parse("/x/y/z")) == (None, [])


# ---------------------------------------------------------------------------
# PIT under the hashed index
# ---------------------------------------------------------------------------

def test_pit_satisfy_walks_prefixes_not_table():
    pit = Pit()
    pit.insert(Interest(name=Name.parse("/a")), 1, now=0.0)
    pit.insert(Interest(name=Name.parse("/a/b")), 2, now=0.0)
    pit.insert(Interest(name=Name.parse("/a/b/c")), 3, now=0.0)
    pit.insert(Interest(name=Name.parse("/unrelated")), 4, now=0.0)
    got = pit.satisfy(Name.parse("/a/b/c/d"))
    assert sorted(str(e.name) for e in got) == ["/a", "/a/b", "/a/b/c"]
    assert len(pit) == 1          # /unrelated untouched


def test_pit_expiry_heap_respects_extension():
    pit = Pit()
    first = Interest(name=Name.parse("/x"), lifetime=1.0)
    pit.insert(first, 1, now=0.0)
    # aggregation extends the deadline; the stale heap record must not kill it
    pit.insert(Interest(name=Name.parse("/x"), lifetime=5.0), 2, now=0.5)
    assert pit.expire(now=2.0) == []
    assert len(pit) == 1
    dead = pit.expire(now=6.0)
    assert len(dead) == 1 and dead[0].in_faces == {1, 2}
    assert len(pit) == 0


def test_pit_expire_after_satisfy_is_clean():
    pit = Pit()
    pit.insert(Interest(name=Name.parse("/x"), lifetime=1.0), 1, now=0.0)
    pit.satisfy(Name.parse("/x"))
    assert pit.expire(now=10.0) == []     # lazy heap record skipped


def test_pit_many_entries_expire_in_order():
    pit = Pit()
    for i in range(50):
        pit.insert(Interest(name=Name.parse(f"/n/{i}"), lifetime=float(i + 1)),
                   1, now=0.0)
    dead = pit.expire(now=10.0)
    assert len(dead) == 10 and len(pit) == 40


# ---------------------------------------------------------------------------
# Content Store under the prefix index
# ---------------------------------------------------------------------------

def test_cs_prefix_index_tracks_eviction():
    cs = ContentStore(capacity=3)
    for i in range(5):
        cs.insert(Data(name=Name.parse(f"/p/{i}/seg"), content=b"x"))
    # /p/0 and /p/1 evicted by LRU; prefix matching must not resurrect them
    assert cs.match(Interest(name=Name.parse("/p/0"), can_be_prefix=True),
                    0.0) is None
    assert cs.match(Interest(name=Name.parse("/p/4"), can_be_prefix=True),
                    0.0) is not None


def test_cs_evict_prefix_uses_index():
    cs = ContentStore()
    for i in range(4):
        cs.insert(Data(name=Name.parse(f"/ckpt/run1/{i}"), content=b"x"))
    cs.insert(Data(name=Name.parse("/ckpt/run2/0"), content=b"x"))
    assert cs.evict_prefix(Name.parse("/ckpt/run1")) == 4
    assert len(cs) == 1
    assert cs.match(Interest(name=Name.parse("/ckpt/run1/0")), 0.0) is None
    assert cs.match(Interest(name=Name.parse("/ckpt/run2/0")), 0.0) is not None


def test_cs_prefix_match_skips_stale_finds_fresh():
    cs = ContentStore()
    cs.insert(Data(name=Name.parse("/a/stale"), content=b"s", freshness=1.0,
                   created_at=0.0))
    cs.insert(Data(name=Name.parse("/a/zfresh"), content=b"f", freshness=100.0,
                   created_at=0.0))
    hit = cs.match(Interest(name=Name.parse("/a"), can_be_prefix=True,
                            must_be_fresh=True), now=50.0)
    assert hit is not None and hit.content == b"f"


def test_cs_reinsert_same_name_keeps_index_consistent():
    cs = ContentStore(capacity=4)
    for _ in range(3):
        cs.insert(Data(name=Name.parse("/dup/x"), content=b"x"))
    assert len(cs) == 1
    assert cs.evict_prefix(Name.parse("/dup")) == 1
    assert len(cs) == 0


def test_fib_scales_lookup_cost_not_with_table_size():
    """The structural property the trie exists for: lookup touches O(name)
    trie nodes, never the announced-prefix population."""
    fib = Fib()
    for i in range(2000):
        fib.register(Name.parse(f"/lidc/compute/app{i % 17}/arch{i}"), 1 + i % 4)
    probes = itertools.count()

    class CountingDict(dict):
        def get(self, k, default=None):
            next(probes)
            return dict.get(self, k, default)

    # instrument every children dict on the lookup path
    def wrap(node):
        node.children = CountingDict(node.children)
    wrap(fib._root)
    for child in list(fib._root.children.values()):
        wrap(child)
    fib.lookup(Name.parse("/lidc/compute/app3/arch3/job/k=1"))
    assert next(probes) < 10   # a handful of child probes, not thousands
