"""MeshTopology: multi-hop routing, withdrawal, churn, re-convergence."""

import pytest

from repro.core.forwarder import Network
from repro.core.names import Name
from repro.core.overlay import MeshTopology
from repro.core.packets import Data
from repro.core.strategy import AdaptiveStrategy


def _serve(mesh, origin, prefix, tag=b"v"):
    calls = {"n": 0}

    def handler(interest, publish, now):
        calls["n"] += 1
        return Data(name=interest.name, content=tag, created_at=now,
                    freshness=30.0)

    mesh.attach_producer(origin, Name.parse(prefix), handler)
    return calls


@pytest.mark.parametrize("kind", MeshTopology.KINDS)
def test_mesh_end_to_end_fetch(kind):
    net = Network()
    mesh = MeshTopology(net, 12, kind, seed=3,
                        strategy_factory=lambda i: AdaptiveStrategy())
    calls = _serve(mesh, 7, "/svc/a")
    c = mesh.consumer_at(0)
    box = c.get(Name.parse("/svc/a/x"))
    assert box["data"].content == b"v" and calls["n"] == 1
    # repeat is served from a Content Store along the path
    box2 = c.get(Name.parse("/svc/a/x"))
    assert box2["data"].content == b"v" and calls["n"] == 1


def test_mesh_every_node_reaches_every_announcement():
    net = Network()
    mesh = MeshTopology(net, 10, "random", seed=5)
    for origin in range(10):
        _serve(mesh, origin, f"/svc/n{origin}")
    for src in (0, 4, 9):
        c = mesh.consumer_at(src)
        for origin in range(10):
            box = c.get(Name.parse(f"/svc/n{origin}/q{src}"))
            assert "data" in box, (src, origin)


def test_mesh_withdraw_removes_only_that_origin():
    net = Network()
    mesh = MeshTopology(net, 8, "ring")
    _serve(mesh, 2, "/svc/shared")
    _serve(mesh, 6, "/svc/shared", tag=b"w")
    mesh.withdraw(2, Name.parse("/svc/shared"))
    c = mesh.consumer_at(0)
    box = c.get(Name.parse("/svc/shared/x"))
    assert "data" in box            # origin 6 still serves
    # node 3 (adjacent-ish to 2) must no longer hold a route through 2 only
    assert len(mesh.nodes[0].fib) >= 1


def test_mesh_graceful_leave_then_fetch_from_backup():
    net = Network()
    mesh = MeshTopology(net, 8, "ring")
    calls2 = _serve(mesh, 2, "/svc/a")
    calls6 = _serve(mesh, 6, "/svc/a", tag=b"backup")
    c = mesh.consumer_at(0)
    assert "data" in c.get(Name.parse("/svc/a/1"))
    mesh.leave(2)
    box = c.get(Name.parse("/svc/a/2"))
    assert box["data"].content == b"backup"
    assert calls6["n"] >= 1 and calls2["n"] <= 1


def test_mesh_fail_heal_refresh_cycle():
    net = Network()
    mesh = MeshTopology(net, 9, "tree")
    calls = _serve(mesh, 8, "/svc/deep")
    c = mesh.consumer_at(0)
    assert "data" in c.get(Name.parse("/svc/deep/1"))
    mesh.fail_node(8)
    mesh.refresh_routes()           # converge around the dark node
    box = c.get(Name.parse("/svc/deep/2"), retries=1, lifetime=0.5)
    assert "data" not in box        # sole producer is dark: must fail
    mesh.heal_node(8)
    mesh.refresh_routes()
    assert "data" in c.get(Name.parse("/svc/deep/3"))
    assert calls["n"] == 2


def test_mesh_join_mid_run_becomes_reachable():
    net = Network()
    mesh = MeshTopology(net, 6, "ring")
    idx = mesh.add_node()
    mesh.connect(idx, 0)
    mesh.connect(idx, 3)
    calls = _serve(mesh, idx, "/svc/new")
    c = mesh.consumer_at(4)
    assert "data" in c.get(Name.parse("/svc/new/x"))
    assert calls["n"] == 1


def test_mesh_equal_cost_multipath_installed():
    net = Network()
    mesh = MeshTopology(net, 6, "ring")    # even ring: two equal paths
    _serve(mesh, 3, "/svc/m")
    mesh.converge()                 # routes arrive by gossip, not fiat
    # node 0 is antipodal to 3: both ring directions are shortest
    hops = mesh.nodes[0].fib.nexthops(Name.parse("/svc/m"))
    assert len(hops) >= 2
    assert min(h.cost for h in hops.values()) == 3.0


def test_mesh_down_nodes_excluded_after_reconvergence():
    net = Network()
    mesh = MeshTopology(net, 7, "ring")
    _serve(mesh, 3, "/svc/r")
    mesh.converge()
    mesh.fail_node(2)
    mesh.converge()                 # neighbors detect + triggered updates
    # node 1's re-converged route to 3 must go the long way (via 0), not via 2
    face_to_2 = mesh.faces[(1, 2)].face_id
    hops = mesh.nodes[1].fib.nexthops(Name.parse("/svc/r"))
    assert face_to_2 not in hops and len(hops) >= 1


def test_mesh_withdraw_anycast_keeps_other_origins_routes():
    net = Network()
    mesh = MeshTopology(net, 6, "ring")
    _serve(mesh, 2, "/svc/any")
    _serve(mesh, 3, "/svc/any", tag=b"other")
    mesh.converge()
    # node 0's face toward 1 carries routes for BOTH origins' announcements
    face01 = mesh.faces[(0, 1)].face_id
    assert face01 in mesh.nodes[0].fib.nexthops(Name.parse("/svc/any"))
    mesh.withdraw(3, Name.parse("/svc/any"))
    mesh.converge()
    # origin 2 still reaches through that shared face — a per-origin,
    # sequence-gated withdrawal cannot sever another origin's routes
    assert face01 in mesh.nodes[0].fib.nexthops(Name.parse("/svc/any"))
    assert "data" in mesh.consumer_at(0).get(Name.parse("/svc/any/q"))


def test_mesh_heal_keeps_links_to_still_down_neighbors_cut():
    net = Network()
    mesh = MeshTopology(net, 6, "ring")
    mesh.fail_node(2)
    mesh.fail_node(3)
    mesh.heal_node(2)
    assert mesh.faces[(2, 3)].down and mesh.faces[(3, 2)].down
    assert not mesh.faces[(2, 1)].down
    mesh.heal_node(3)
    assert not mesh.faces[(2, 3)].down
