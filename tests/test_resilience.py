"""Unified resilience policy: named schedules, budgets, breakers, hedging.

Two properties anchor this file:

* **legacy equivalence** — every named default in core/resilience.py
  reproduces the hard-coded constant it replaced, both as a delay series
  (unit tests) and end-to-end: with faults off, a scenario run under the
  default policies and the same scenario run under explicitly-constructed
  legacy-literal policies produce bit-identical ``(t, seq)`` event traces;
* **determinism** — jitter, budgets and breakers advance only on the
  virtual clock and hashed keys, never wall-clock entropy, so seeded
  scenarios replay exactly.
"""

import pytest

from repro.core.forwarder import Consumer, Forwarder, Nack, Network, link
from repro.core.names import Name
from repro.core.packets import Data
from repro.core.resilience import (CONSUMER_EXPRESS, ENGINE_BUSY,
                                   ENGINE_EXPRESS, ENGINE_NOROUTE,
                                   ENGINE_STAGE, FETCH_BACKOFF,
                                   NOROUTE_FAST_RETRY, SESSION_EXPRESS,
                                   SESSION_RESUBMIT, SPILL_RETRY,
                                   CircuitBreaker, RetryBudget, RetryPolicy)
from repro.core.strategy import AdaptiveStrategy
from repro.workflow import WorkflowEngine, WorkflowSpec
from repro.workflow.apps import build_workflow_fleet


# ---------------------------------------------------------------------------
# named defaults == legacy literals (the auditable migration contract)
# ---------------------------------------------------------------------------

def test_noroute_policy_reproduces_legacy_backoff_series():
    # was: st["noroute_retries"] < 6 with backoff = 0.02 * 2 ** (n - 1)
    assert [NOROUTE_FAST_RETRY.delay(n) for n in range(1, 7)] \
        == [0.02 * 2 ** (n - 1) for n in range(1, 7)]
    assert NOROUTE_FAST_RETRY.allows(6) and not NOROUTE_FAST_RETRY.allows(7)


def test_engine_busy_policy_is_linear_in_poll_interval():
    # was: busy_retries < 4 with delay = poll_interval * busy_retries
    for poll in (0.25, 1.0, 3.0):
        scaled = ENGINE_BUSY.scaled(poll)
        assert [scaled.delay(n) for n in range(1, 5)] \
            == [poll * n for n in range(1, 5)]
    assert ENGINE_BUSY.allows(4) and not ENGINE_BUSY.allows(5)


def test_fetch_backoff_doubles_and_caps_at_64():
    # was: backoff = min(backoff * 2, 64.0) starting from 1.0
    series = [FETCH_BACKOFF.delay(n) for n in range(1, 10)]
    assert series == [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 64.0, 64.0]


def test_retry_caps_match_legacy_constants():
    assert CONSUMER_EXPRESS.max_retries == 3       # Consumer.express default
    assert ENGINE_EXPRESS.max_retries == 3         # engine express_retries
    assert ENGINE_NOROUTE.max_retries == 3         # engine noroute retries
    assert ENGINE_STAGE.max_attempts == 4          # max_stage_attempts
    assert SESSION_EXPRESS.max_retries == 8        # serve express retries
    assert SESSION_RESUBMIT.max_retries == 8       # serve max_resubmits
    assert SPILL_RETRY.max_retries == 1            # gateway spill attempt
    assert FETCH_BACKOFF.max_retries == 10         # fetcher max_retries


def test_delay_validates_and_jitter_is_deterministic():
    p = RetryPolicy(max_retries=3, base_delay=0.1, jitter=0.5)
    with pytest.raises(ValueError):
        p.delay(0)
    # same (key, retry) -> same jittered delay; different keys diverge
    assert p.delay(2, key="a") == p.delay(2, key="a")
    assert p.delay(2, key="a") != p.delay(2, key="b")
    base = RetryPolicy(max_retries=3, base_delay=0.1).delay(2)
    assert base <= p.delay(2, key="a") <= base * 1.5
    # the default policies carry no jitter: delays are exact legacy values
    assert NOROUTE_FAST_RETRY.jitter == 0.0


def test_scaled_preserves_infinite_cap():
    scaled = ENGINE_BUSY.scaled(0.25)
    assert scaled.max_delay == float("inf")
    assert scaled.max_retries == ENGINE_BUSY.max_retries
    capped = FETCH_BACKOFF.scaled(2.0)
    assert capped.max_delay == 128.0


# ---------------------------------------------------------------------------
# retry budgets
# ---------------------------------------------------------------------------

def test_retry_budget_spends_burst_then_denies():
    b = RetryBudget(rate=1.0, burst=2.0)
    assert b.try_spend("k", now=0.0)
    assert b.try_spend("k", now=0.0)
    assert not b.try_spend("k", now=0.0)       # burst exhausted
    assert (b.spent, b.denied) == (2, 1)
    assert b.try_spend("k", now=1.0)           # 1 token/s refilled
    assert b.try_spend("other", now=0.0)       # keys are independent


def test_retry_budget_refill_caps_at_burst():
    b = RetryBudget(rate=100.0, burst=1.0)
    assert b.try_spend("k", now=0.0)
    assert b.try_spend("k", now=10.0)
    assert not b.try_spend("k", now=10.0)      # refill capped at burst=1


def test_consumer_timeout_retransmits_bounded_by_budget():
    """A dry budget turns the retransmit loop into a prompt failure —
    per-prefix amplification is bounded no matter the per-request cap."""
    net = Network()
    hub = Forwarder(net, "hub")
    leaf = Forwarder(net, "leaf")
    hub_face, _ = link(net, hub, leaf, latency=0.001)
    leaf.attach_producer(Name.parse("/svc"),
                         lambda interest, publish, now: None)  # silent
    hub.register_route(Name.parse("/svc"), hub_face)
    budget = RetryBudget(rate=0.0, burst=1.0)
    c = Consumer(net, hub, retry_budget=budget)
    box = c.get(Name.parse("/svc/x"), retries=5, lifetime=0.2)
    assert "error" in box and "timeout" in box["error"]
    assert c.expressed == 2            # initial + the single budgeted retry
    assert budget.spent == 1 and budget.denied == 1


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

def test_breaker_opens_after_threshold_and_probes_after_cooloff():
    br = CircuitBreaker(fail_threshold=3, cooloff=1.0)
    for i in range(2):
        br.record("up", ok=False, now=float(i))
        assert br.state("up") == "closed"
    br.record("up", ok=False, now=2.0)
    assert br.state("up") == "open" and br.opened == 1
    assert not br.allow("up", now=2.5)          # inside cooloff: denied
    assert br.allow("up", now=3.0)              # cooloff over: one probe
    assert br.state("up") == "half-open"
    br.record("up", ok=True, now=3.1)           # probe succeeded
    assert br.state("up") == "closed"
    assert br.open_keys() == ()


def test_breaker_failed_probe_reopens_with_fresh_cooloff():
    br = CircuitBreaker(fail_threshold=1, cooloff=1.0)
    br.record("up", ok=False, now=0.0)
    assert br.allow("up", now=1.0)
    br.record("up", ok=False, now=1.0)          # probe failed
    assert br.state("up") == "open" and br.opened == 2
    assert not br.allow("up", now=1.5)
    assert br.allow("up", now=2.0)


def test_breaker_stuck_half_open_readmits_probe_each_cooloff():
    """An admitted probe that is never routed (the strategy preferred
    another hop) must not quarantine a healed upstream forever."""
    br = CircuitBreaker(fail_threshold=1, cooloff=1.0)
    br.record("up", ok=False, now=0.0)
    assert br.allow("up", now=1.0)              # probe 1 admitted, unanswered
    assert not br.allow("up", now=1.5)          # within the probe window
    assert br.allow("up", now=2.0)              # re-admitted, not stuck


def test_breaker_success_forgets_failure_history():
    br = CircuitBreaker(fail_threshold=3)
    br.record("up", ok=False, now=0.0)
    br.record("up", ok=False, now=0.0)
    br.record("up", ok=True, now=0.0)
    for _ in range(2):
        br.record("up", ok=False, now=0.0)      # streak restarted from 0
    assert br.state("up") == "closed"


# ---------------------------------------------------------------------------
# breaker wired into AdaptiveStrategy: quarantine + probe-back-in
# ---------------------------------------------------------------------------

def _producer(node, prefix, value=b"v", fail_box=None):
    calls = {"n": 0}

    def handler(interest, publish, now):
        calls["n"] += 1
        if fail_box is not None and fail_box.get("fail"):
            return Nack(interest, "synthetic")
        return Data(name=interest.name, content=value, created_at=now,
                    freshness=10.0)

    node.attach_producer(Name.parse(prefix), handler)
    return calls


def _star(strategy, n=3):
    net = Network()
    hub = Forwarder(net, "hub", strategy=strategy)
    leaves = []
    for i in range(n):
        leaf = Forwarder(net, f"leaf{i}")
        hub_face, _ = link(net, hub, leaf, latency=0.001)
        leaves.append((leaf, hub_face))
        hub.register_route(Name.parse("/svc"), hub_face, cost=1.0 + i)
    return net, hub, leaves


def test_strategy_quarantines_open_upstream_and_probes_back_in():
    # one failure trips the circuit (the strategy's own EWMA shifts
    # traffic before a longer streak could accumulate), and the cooloff
    # spans several requests so the quarantine window is observable
    breaker = CircuitBreaker(fail_threshold=1, cooloff=30.0)
    strat = AdaptiveStrategy(probe_fanout=1, explore_every=4,
                             breaker=breaker)
    net, hub, leaves = _star(strat)
    fail0 = {"fail": False}
    calls = [_producer(leaves[0][0], "/svc", fail_box=fail0)]
    calls += [_producer(leaf, "/svc") for leaf, _ in leaves[1:]]
    c = Consumer(net, hub)
    for i in range(4):
        assert "data" in c.get(Name.parse(f"/svc/w{i}"))
    face0 = leaves[0][1].face_id
    # leaf0 starts NACKing: the first failure opens the circuit, and every
    # request inside the cooloff window skips leaf0 entirely
    fail0["fail"] = True
    for i in range(6):
        assert "data" in c.get(Name.parse(f"/svc/b{i}"))
    assert breaker.state(face0) != "closed"
    assert breaker.opened >= 1
    assert strat.quarantine_skips > 0
    assert calls[0]["n"] <= 4 + 2      # at most the tripping call + a probe
    # leaf0 heals; once the cooloff expires a probe is admitted, succeeds,
    # and closes the circuit — leaf0 (cheapest) wins traffic back
    fail0["fail"] = False
    healed = calls[0]["n"]
    for i in range(30):
        assert "data" in c.get(Name.parse(f"/svc/h{i}"))
    assert calls[0]["n"] > healed
    assert breaker.state(face0) == "closed"


def test_breaker_never_blackholes_the_only_route():
    breaker = CircuitBreaker(fail_threshold=1, cooloff=10.0)
    strat = AdaptiveStrategy(probe_fanout=1, breaker=breaker)
    net, hub, leaves = _star(strat, n=1)
    flaky = {"fail": True}
    calls = _producer(leaves[0][0], "/svc", fail_box=flaky)
    c = Consumer(net, hub)
    c.get(Name.parse("/svc/a"), retries=0)       # opens the breaker
    assert breaker.state(leaves[0][1].face_id) != "closed"
    flaky["fail"] = False
    # sole upstream: _admit must fall back to it rather than drop to NACK
    box = c.get(Name.parse("/svc/b"), retries=0)
    assert box["data"].content == b"v"
    assert calls["n"] == 2


# ---------------------------------------------------------------------------
# hedged Interests
# ---------------------------------------------------------------------------

def test_hedged_interest_cuts_tail_and_dedupes_loser():
    net = Network()
    hub = Forwarder(net, "hub")
    slow = Forwarder(net, "slow")
    fast = Forwarder(net, "fast")
    f_slow, _ = link(net, hub, slow, latency=0.001)
    f_fast, _ = link(net, hub, fast, latency=0.001)

    def slow_handler(interest, publish, now):
        d = Data(name=interest.name, content=b"slow", created_at=now,
                 freshness=10.0)
        net.schedule(1.0, lambda: publish(d))    # the straggler
        return None

    slow.attach_producer(Name.parse("/svc"), slow_handler)
    _producer(fast, "/svc", value=b"fast")
    hub.register_route(Name.parse("/svc"), f_slow, cost=1.0)  # preferred
    hub.register_route(Name.parse("/svc"), f_fast, cost=2.0)
    c = Consumer(net, hub)
    got = []
    from repro.core.packets import Interest
    c.express(Interest(name=Name.parse("/svc/x"), lifetime=4.0),
              on_data=lambda d: got.append((net.now, d)),
              hedge_delay=0.05)
    net.run()
    assert c.hedges == 1
    assert len(got) == 1                   # PIT deduped the race loser
    t, d = got[0]
    assert d.content == b"fast"
    assert t < 0.1                         # hedged answer, not the 1s tail


def test_hedge_noop_when_answer_beats_the_delay():
    net = Network()
    hub = Forwarder(net, "hub")
    leaf = Forwarder(net, "leaf")
    hub_face, _ = link(net, hub, leaf, latency=0.001)
    _producer(leaf, "/svc")
    hub.register_route(Name.parse("/svc"), hub_face)
    c = Consumer(net, hub)
    got = []
    from repro.core.packets import Interest
    c.express(Interest(name=Name.parse("/svc/x")),
              on_data=got.append, hedge_delay=0.5)
    net.run()
    assert len(got) == 1 and c.hedges == 0
    assert c.expressed == 1                # hedging cost nothing


# ---------------------------------------------------------------------------
# trace equivalence: default policies == explicit legacy literals
# ---------------------------------------------------------------------------

_LEGACY_NOROUTE = RetryPolicy(max_retries=6, base_delay=0.02, factor=2.0)
_LEGACY_EXPRESS = RetryPolicy(max_retries=3)
_LEGACY_BUSY = RetryPolicy(max_retries=4, base_delay=1.0, linear=True)


def _noroute_trace(engine, policies):
    """The no-route fast-retry loop, hit end-to-end: a hub with no routes
    NACKs every Interest; the consumer walks the full backoff schedule."""
    net = Network(engine=engine)
    net.trace = []
    hub = Forwarder(net, "hub")
    c = Consumer(net, hub, **policies)
    box = c.get(Name.parse("/nowhere/x"), lifetime=1.0)
    assert "error" in box
    return net.trace


@pytest.mark.parametrize("engine", ["calendar", "heap"])
def test_consumer_policy_migration_is_trace_identical(engine):
    default = _noroute_trace(engine, {})
    explicit = _noroute_trace(engine, {"noroute_policy": _LEGACY_NOROUTE,
                                       "express_policy": _LEGACY_EXPRESS})
    assert default == explicit and len(default) > 0


def _workflow_trace(engine_policies):
    # pin the process-global job-id counter so back-to-back scenarios mint
    # identical ids (payload sizes embed them)
    import itertools

    from repro.core import jobs
    jobs._job_seq = itertools.count(1000)
    system, log = build_workflow_fleet(3, chips=4)
    system.lake.put_bytes(Name.parse("/lidc/data/reads/eq"),
                          bytes(range(256)) * 512)
    wf = (WorkflowSpec("eq")
          .stage("shard", "wf-shard", inputs=["/lidc/data/reads/eq"],
                 parts=3)
          .stage("align", "wf-align", inputs=["@shard"], fanout=3)
          .stage("merge", "wf-merge", inputs=["@align"])
          .compile())
    system.net.trace = []
    eng = WorkflowEngine(system.net, system.overlay.edge, **engine_policies)
    run = eng.run(wf)
    assert run.complete, run.stage_report()
    return system.net.trace, run.trace


def test_engine_policy_migration_is_trace_identical():
    net_a, run_a = _workflow_trace({})
    net_b, run_b = _workflow_trace({"noroute_policy": RetryPolicy(3),
                                    "busy_policy": _LEGACY_BUSY})
    assert run_a == run_b
    assert net_a == net_b and len(net_a) > 0
